//! The three evaluation scenarios of §V.

use crate::util::Rng;

/// Which testbed manipulation is active.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scenario {
    /// No manipulation (the `n_f = 0` baseline of Fig. 6).
    None,
    /// Scenario 1: extra exponential transmission delay with mean
    /// `lambda_tr × T̄_tr` added to every worker's round trip.
    Straggling { lambda_tr: f64 },
    /// Scenario 2: `n_f` random workers fail in each execution round.
    Failures { n_f: usize },
    /// Scenario 3: scenario 2 plus worker 0 as a chronic straggler whose
    /// compute runs `slowdown`× slower (paper observes ≈1.68×).
    FailuresPlusStraggler { n_f: usize, slowdown: f64 },
}

impl Scenario {
    pub fn n_f(&self) -> usize {
        match self {
            Scenario::Failures { n_f } | Scenario::FailuresPlusStraggler { n_f, .. } => *n_f,
            _ => 0,
        }
    }

    pub fn lambda_tr(&self) -> f64 {
        match self {
            Scenario::Straggling { lambda_tr } => *lambda_tr,
            _ => 0.0,
        }
    }

    /// Per-round failing-worker draw.
    pub fn draw_failures(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        let n_f = self.n_f().min(n.saturating_sub(1));
        if n_f == 0 {
            Vec::new()
        } else {
            rng.sample_distinct(n, n_f)
        }
    }

    /// Compute slowdown of worker `i`.
    pub fn cmp_slowdown(&self, worker: usize) -> f64 {
        match self {
            Scenario::FailuresPlusStraggler { slowdown, .. } if worker == 0 => *slowdown,
            _ => 1.0,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Scenario::None => "none".into(),
            Scenario::Straggling { lambda_tr } => format!("s1(lambda={lambda_tr})"),
            Scenario::Failures { n_f } => format!("s2(n_f={n_f})"),
            Scenario::FailuresPlusStraggler { n_f, slowdown } => {
                format!("s3(n_f={n_f},x{slowdown})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_respect_nf() {
        let mut rng = Rng::new(3);
        let s = Scenario::Failures { n_f: 2 };
        for _ in 0..20 {
            let f = s.draw_failures(10, &mut rng);
            assert_eq!(f.len(), 2);
            assert!(f[0] != f[1]);
        }
        assert!(Scenario::None.draw_failures(10, &mut rng).is_empty());
    }

    #[test]
    fn chronic_straggler_only_worker_zero() {
        let s = Scenario::FailuresPlusStraggler { n_f: 1, slowdown: 1.68 };
        assert_eq!(s.cmp_slowdown(0), 1.68);
        assert_eq!(s.cmp_slowdown(3), 1.0);
    }
}
