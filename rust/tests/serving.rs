//! Serving-API suite: the non-blocking submit/handle front-end must
//! produce the same outputs as the blocking batch paths, deliver
//! handles awaited in any order, reject on a full admission queue while
//! in-flight requests still complete, shed expired deadlines, and drain
//! gracefully. Runs without `artifacts/`.
//!
//! The `chaos_*` tests are the fault-injection suite: chronic
//! stragglers, per-round failures, and extra send delay are wired
//! through `WorkerFaults` into a live `InferenceServer` stream — under
//! every engine configuration (sequential, coalesced, multi-slot) the
//! outputs must stay bitwise-equal to local inference on the uncoded
//! path / within decode tolerance under MDS, and every handle must
//! resolve (no wedge).

use std::sync::Arc;
use std::time::Duration;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, PoolOptions,
    ScenarioFaults, SchemeKind, ServeError, ServerConfig, SubmitError, WorkerFaults,
    WorkerHandles,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn inputs_for(model_name: &str, count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(model_name: &str, inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn spawn_server(
    scheme: SchemeKind,
    n: usize,
    k: usize,
    faults: Vec<WorkerFaults>,
    config: ServerConfig,
) -> (InferenceServer, WorkerHandles) {
    spawn_server_knobs(scheme, n, k, faults, config, 1, 1)
}

/// `spawn_server` plus the PR-5 engine knobs: cross-request coalescing
/// and intra-worker slots.
fn spawn_server_knobs(
    scheme: SchemeKind,
    n: usize,
    k: usize,
    faults: Vec<WorkerFaults>,
    config: ServerConfig,
    coalesce: usize,
    worker_slots: usize,
) -> (InferenceServer, WorkerHandles) {
    let master_cfg = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        mode: ExecMode::Pipelined,
        coalesce,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn_with(
        "tinyvgg",
        n,
        master_cfg,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots },
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    (InferenceServer::start(master, config), workers)
}

fn stop(server: InferenceServer, workers: WorkerHandles) {
    let master = server.shutdown().unwrap();
    master.shutdown();
    workers.join().unwrap();
}

/// submit+wait must agree with the blocking paths: bitwise with the
/// barrier engine under the deterministic uncoded decode, and within
/// decode tolerance of local inference under MDS.
#[test]
fn submit_wait_matches_barrier_and_local() {
    let inputs = inputs_for("tinyvgg", 3, 901);
    let want = local_refs("tinyvgg", &inputs);

    // Barrier reference (uncoded, n == k: exact passthrough decode).
    let config = MasterConfig {
        scheme: SchemeKind::Uncoded,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::RoundBarrier,
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        3,
        config,
        Arc::new(FallbackProvider::new()),
        (0..3).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let barrier = cluster.master.infer_batch(&inputs).unwrap();
    cluster.shutdown().unwrap();

    let (server, workers) = spawn_server(
        SchemeKind::Uncoded,
        3,
        3,
        (0..3).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (handle, (b, _)) in handles.into_iter().zip(&barrier) {
        let (out, metrics) = handle.wait().unwrap();
        assert_eq!(out.data, b.data, "serving diverged from the barrier engine");
        assert!(metrics.layers.iter().any(|l| l.distributed));
    }
    stop(server, workers);

    // MDS through the server: within decode tolerance of local.
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        let err = out.max_abs_diff(want);
        assert!(err < 2e-2, "served output off local by {err}");
    }
    stop(server, workers);
}

/// Handles are independent completion tokens: awaiting them in reverse
/// submission order still yields each request's own answer.
#[test]
fn handles_awaited_out_of_order() {
    let inputs = inputs_for("tinyvgg", 4, 902);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let mut handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    let mut results: Vec<Option<Tensor>> = (0..inputs.len()).map(|_| None).collect();
    while let Some(handle) = handles.pop() {
        let idx = handles.len(); // reverse order: last submitted first
        let (out, _) = handle.wait().unwrap();
        results[idx] = Some(out);
    }
    for (got, want) in results.iter().zip(&want) {
        let err = got.as_ref().unwrap().max_abs_diff(want);
        assert!(err < 2e-2, "out-of-order wait returned wrong output ({err})");
    }
    stop(server, workers);
}

/// Backpressure: a full admission queue rejects with `QueueFull` while
/// the in-flight requests still complete — and capacity frees up again
/// once they do.
#[test]
fn full_queue_rejects_then_recovers() {
    let inputs = inputs_for("tinyvgg", 4, 903);
    let want = local_refs("tinyvgg", &inputs);
    // Slow the pool (20 ms per reply) so the queue stays occupied for
    // the whole submit burst.
    let faults: Vec<WorkerFaults> = (0..3)
        .map(|_| WorkerFaults::with_send_delay(0.020))
        .collect();
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        3,
        2,
        faults,
        ServerConfig {
            queue_capacity: 3,
            ..Default::default()
        },
    );
    let handles: Vec<_> = inputs[..3]
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    // 4th submission: the bounded queue must push back.
    match server.submit(InferenceRequest::new(inputs[3].clone())) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|h| h.id())),
    }
    assert_eq!(server.stats().rejected_queue_full, 1);
    // The in-flight requests are unaffected by the rejection.
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    // Queue drained: the same request is admitted now.
    let h = server.submit(InferenceRequest::new(inputs[3].clone())).unwrap();
    let (out, _) = h.wait().unwrap();
    assert!(out.max_abs_diff(&want[3]) < 2e-2);
    stop(server, workers);
}

/// An already-expired deadline is shed at dispatch — and the shed
/// request does not disturb its neighbours.
#[test]
fn expired_deadline_is_shed() {
    let inputs = inputs_for("tinyvgg", 2, 904);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let doomed = server
        .submit(InferenceRequest::new(inputs[0].clone()).with_deadline(Duration::ZERO))
        .unwrap();
    let fine = server.submit(InferenceRequest::new(inputs[1].clone())).unwrap();
    match doomed.wait() {
        Err(ServeError::DeadlineShed { .. }) => {}
        other => panic!("expected a deadline shed, got {other:?}"),
    }
    let (out, _) = fine.wait().unwrap();
    assert!(out.max_abs_diff(&want[1]) < 2e-2);
    assert_eq!(server.stats().shed, 1);
    assert_eq!(server.stats().completed, 1);
    stop(server, workers);
}

/// drain() waits for in-flight work, then refuses new submissions; the
/// earlier handles still hold their results.
#[test]
fn drain_rejects_new_submissions() {
    let inputs = inputs_for("tinyvgg", 2, 905);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    server.drain();
    assert_eq!(
        server
            .submit(InferenceRequest::new(inputs[0].clone()))
            .err()
            .unwrap(),
        SubmitError::ShuttingDown
    );
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    assert_eq!(server.stats().open, 0);
    stop(server, workers);
}

// ====================================================================
// Chaos suite: faults through the live serving stream, under every
// engine configuration (sequential / coalesced / multi-slot).
// ====================================================================

/// The engine configurations every chaos case must survive unchanged:
/// the PR-4 baseline, coalescing alone, and coalescing + worker slots.
const CHAOS_KNOBS: [(usize, usize); 3] = [(1, 1), (4, 1), (4, 2)];

/// Stream `inputs` through a server and wait for everything, asserting
/// no handle wedges and every request succeeds.
fn stream_all(server: &InferenceServer, inputs: &[Tensor]) -> Vec<Tensor> {
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, input)| {
            server
                .submit(InferenceRequest::new(input.clone()).with_priority((i % 3) as u8))
                .unwrap()
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.wait().expect("chaos request failed").0)
        .collect()
}

/// A chronic ~3× straggler in the pool: MDS(k=3, n=4) decodes from the
/// healthy three; every streamed request completes within decode
/// tolerance of local, with no wedged handle, under every knob setting.
#[test]
fn chaos_chronic_straggler_stream() {
    let inputs = inputs_for("tinyvgg", 6, 910);
    let want = local_refs("tinyvgg", &inputs);
    for (coalesce, slots) in CHAOS_KNOBS {
        let mut faults: Vec<WorkerFaults> = (0..4).map(|_| WorkerFaults::none()).collect();
        faults[0] = WorkerFaults::none().slowdown(3.0);
        let (server, workers) = spawn_server_knobs(
            SchemeKind::Mds,
            4,
            3,
            faults,
            ServerConfig::default(),
            coalesce,
            slots,
        );
        let outs = stream_all(&server, &inputs);
        for (got, want) in outs.iter().zip(&want) {
            let err = got.max_abs_diff(want);
            assert!(
                err < 2e-2,
                "coalesce={coalesce} slots={slots}: straggler run off local by {err}"
            );
        }
        let stats = server.stats();
        assert_eq!(stats.completed, inputs.len() as u64);
        assert_eq!(stats.open, 0);
        stop(server, workers);
    }
}

/// Per-round injected failures (scenario 2) on the uncoded path: every
/// failed shard is re-dispatched and the streamed outputs stay
/// BITWISE-equal to local inference — re-dispatch reuses the exact
/// frame bytes and the batched GEMM is bitwise per payload.
#[test]
fn chaos_per_round_failures_uncoded_bitwise() {
    let inputs = inputs_for("tinyvgg", 5, 911);
    let want = local_refs("tinyvgg", &inputs);
    for (coalesce, slots) in CHAOS_KNOBS {
        let mut rng = Rng::new(0xFA11 ^ coalesce as u64);
        let faults = ScenarioFaults::failures(3, 1, 256, &mut rng);
        let (server, workers) = spawn_server_knobs(
            SchemeKind::Uncoded,
            3,
            3,
            faults,
            ServerConfig::default(),
            coalesce,
            slots,
        );
        let outs = stream_all(&server, &inputs);
        for (got, want) in outs.iter().zip(&want) {
            assert_eq!(
                got.data, want.data,
                "coalesce={coalesce} slots={slots}: uncoded chaos output not bitwise-local"
            );
        }
        stop(server, workers);
    }
}

/// Scenario-1 extra send delay on every worker while the submit stream
/// stays open: stragglers get cancelled mid-flight, nothing wedges, and
/// MDS outputs stay within decode tolerance of local.
#[test]
fn chaos_send_delay_open_stream() {
    let inputs = inputs_for("tinyvgg", 8, 912);
    let want = local_refs("tinyvgg", &inputs);
    for (coalesce, slots) in CHAOS_KNOBS {
        let faults = ScenarioFaults::straggling(4, 0.8, 0.01);
        let (server, workers) = spawn_server_knobs(
            SchemeKind::Mds,
            4,
            2,
            faults,
            ServerConfig {
                queue_capacity: inputs.len(),
                ..Default::default()
            },
            coalesce,
            slots,
        );
        let outs = stream_all(&server, &inputs);
        for (got, want) in outs.iter().zip(&want) {
            let err = got.max_abs_diff(want);
            assert!(
                err < 2e-2,
                "coalesce={coalesce} slots={slots}: send-delay run off local by {err}"
            );
        }
        let stats = server.stats();
        assert_eq!(stats.completed, inputs.len() as u64);
        assert_eq!(stats.failed, 0);
        stop(server, workers);
    }
}

/// Mixed chaos — failures AND a chronic straggler AND send delay — with
/// deadline-free streaming: the full fault cocktail must still deliver
/// every answer within tolerance under the coalesced multi-slot engine.
#[test]
fn chaos_mixed_faults_coalesced_multislot() {
    let inputs = inputs_for("tinyvgg", 6, 913);
    let want = local_refs("tinyvgg", &inputs);
    let mut rng = Rng::new(0x5EED);
    let mut faults = ScenarioFaults::failures_plus_straggler(4, 1, 256, &mut rng);
    for f in &mut faults {
        f.extra_send_delay_mean = 0.004;
    }
    let (server, workers) = spawn_server_knobs(
        SchemeKind::Mds,
        4,
        3,
        faults,
        ServerConfig::default(),
        4,
        2,
    );
    let outs = stream_all(&server, &inputs);
    for (got, want) in outs.iter().zip(&want) {
        let err = got.max_abs_diff(want);
        assert!(err < 2e-2, "mixed chaos run off local by {err}");
    }
    let stats = server.stats();
    assert_eq!(stats.completed, inputs.len() as u64);
    assert_eq!(stats.open, 0);
    stop(server, workers);
}

/// A barrier-mode master behind the server serves sequentially (one in
/// flight) but yields the same answers.
#[test]
fn server_over_barrier_mode_master_serves_sequentially() {
    let inputs = inputs_for("tinyvgg", 2, 906);
    let want = local_refs("tinyvgg", &inputs);
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::RoundBarrier,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn(
        "tinyvgg",
        4,
        config,
        Arc::new(FallbackProvider::new()),
        (0..4).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    let server = InferenceServer::start(master, ServerConfig::default());
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    stop(server, workers);
}
