//! Serving-API suite: the non-blocking submit/handle front-end must
//! produce the same outputs as the blocking batch paths, deliver
//! handles awaited in any order, reject on a full admission queue while
//! in-flight requests still complete, shed expired deadlines, and drain
//! gracefully. Runs without `artifacts/`.

use std::sync::Arc;
use std::time::Duration;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, SchemeKind,
    ServeError, ServerConfig, SubmitError, WorkerFaults, WorkerHandles,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn inputs_for(model_name: &str, count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(model_name: &str, inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn spawn_server(
    scheme: SchemeKind,
    n: usize,
    k: usize,
    faults: Vec<WorkerFaults>,
    config: ServerConfig,
) -> (InferenceServer, WorkerHandles) {
    let master_cfg = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        mode: ExecMode::Pipelined,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn(
        "tinyvgg",
        n,
        master_cfg,
        Arc::new(FallbackProvider::new()),
        faults,
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    (InferenceServer::start(master, config), workers)
}

fn stop(server: InferenceServer, workers: WorkerHandles) {
    let master = server.shutdown().unwrap();
    master.shutdown();
    workers.join().unwrap();
}

/// submit+wait must agree with the blocking paths: bitwise with the
/// barrier engine under the deterministic uncoded decode, and within
/// decode tolerance of local inference under MDS.
#[test]
fn submit_wait_matches_barrier_and_local() {
    let inputs = inputs_for("tinyvgg", 3, 901);
    let want = local_refs("tinyvgg", &inputs);

    // Barrier reference (uncoded, n == k: exact passthrough decode).
    let config = MasterConfig {
        scheme: SchemeKind::Uncoded,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::RoundBarrier,
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        3,
        config,
        Arc::new(FallbackProvider::new()),
        (0..3).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let barrier = cluster.master.infer_batch(&inputs).unwrap();
    cluster.shutdown().unwrap();

    let (server, workers) = spawn_server(
        SchemeKind::Uncoded,
        3,
        3,
        (0..3).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (handle, (b, _)) in handles.into_iter().zip(&barrier) {
        let (out, metrics) = handle.wait().unwrap();
        assert_eq!(out.data, b.data, "serving diverged from the barrier engine");
        assert!(metrics.layers.iter().any(|l| l.distributed));
    }
    stop(server, workers);

    // MDS through the server: within decode tolerance of local.
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        let err = out.max_abs_diff(want);
        assert!(err < 2e-2, "served output off local by {err}");
    }
    stop(server, workers);
}

/// Handles are independent completion tokens: awaiting them in reverse
/// submission order still yields each request's own answer.
#[test]
fn handles_awaited_out_of_order() {
    let inputs = inputs_for("tinyvgg", 4, 902);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let mut handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    let mut results: Vec<Option<Tensor>> = (0..inputs.len()).map(|_| None).collect();
    while let Some(handle) = handles.pop() {
        let idx = handles.len(); // reverse order: last submitted first
        let (out, _) = handle.wait().unwrap();
        results[idx] = Some(out);
    }
    for (got, want) in results.iter().zip(&want) {
        let err = got.as_ref().unwrap().max_abs_diff(want);
        assert!(err < 2e-2, "out-of-order wait returned wrong output ({err})");
    }
    stop(server, workers);
}

/// Backpressure: a full admission queue rejects with `QueueFull` while
/// the in-flight requests still complete — and capacity frees up again
/// once they do.
#[test]
fn full_queue_rejects_then_recovers() {
    let inputs = inputs_for("tinyvgg", 4, 903);
    let want = local_refs("tinyvgg", &inputs);
    // Slow the pool (20 ms per reply) so the queue stays occupied for
    // the whole submit burst.
    let faults: Vec<WorkerFaults> = (0..3)
        .map(|_| WorkerFaults::with_send_delay(0.020))
        .collect();
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        3,
        2,
        faults,
        ServerConfig {
            queue_capacity: 3,
            ..Default::default()
        },
    );
    let handles: Vec<_> = inputs[..3]
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    // 4th submission: the bounded queue must push back.
    match server.submit(InferenceRequest::new(inputs[3].clone())) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {:?}", other.map(|h| h.id())),
    }
    assert_eq!(server.stats().rejected_queue_full, 1);
    // The in-flight requests are unaffected by the rejection.
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    // Queue drained: the same request is admitted now.
    let h = server.submit(InferenceRequest::new(inputs[3].clone())).unwrap();
    let (out, _) = h.wait().unwrap();
    assert!(out.max_abs_diff(&want[3]) < 2e-2);
    stop(server, workers);
}

/// An already-expired deadline is shed at dispatch — and the shed
/// request does not disturb its neighbours.
#[test]
fn expired_deadline_is_shed() {
    let inputs = inputs_for("tinyvgg", 2, 904);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let doomed = server
        .submit(InferenceRequest::new(inputs[0].clone()).with_deadline(Duration::ZERO))
        .unwrap();
    let fine = server.submit(InferenceRequest::new(inputs[1].clone())).unwrap();
    match doomed.wait() {
        Err(ServeError::DeadlineShed { .. }) => {}
        other => panic!("expected a deadline shed, got {other:?}"),
    }
    let (out, _) = fine.wait().unwrap();
    assert!(out.max_abs_diff(&want[1]) < 2e-2);
    assert_eq!(server.stats().shed, 1);
    assert_eq!(server.stats().completed, 1);
    stop(server, workers);
}

/// drain() waits for in-flight work, then refuses new submissions; the
/// earlier handles still hold their results.
#[test]
fn drain_rejects_new_submissions() {
    let inputs = inputs_for("tinyvgg", 2, 905);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn_server(
        SchemeKind::Mds,
        4,
        3,
        (0..4).map(|_| WorkerFaults::none()).collect(),
        ServerConfig::default(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    server.drain();
    assert_eq!(
        server
            .submit(InferenceRequest::new(inputs[0].clone()))
            .err()
            .unwrap(),
        SubmitError::ShuttingDown
    );
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    assert_eq!(server.stats().open, 0);
    stop(server, workers);
}

/// A barrier-mode master behind the server serves sequentially (one in
/// flight) but yields the same answers.
#[test]
fn server_over_barrier_mode_master_serves_sequentially() {
    let inputs = inputs_for("tinyvgg", 2, 906);
    let want = local_refs("tinyvgg", &inputs);
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::RoundBarrier,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn(
        "tinyvgg",
        4,
        config,
        Arc::new(FallbackProvider::new()),
        (0..4).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    let server = InferenceServer::start(master, ServerConfig::default());
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (handle, want) in handles.into_iter().zip(&want) {
        let (out, _) = handle.wait().unwrap();
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    stop(server, workers);
}
