//! Tiled-kernel suite: the multithreaded packed GEMM (`conv::gemm`)
//! against the scalar ikj oracle across tall/skinny/odd-remainder
//! shapes, bitwise determinism across thread counts, the prepacked
//! weight path, and scratch-arena reuse.

use cocoi::conv::gemm::{conv_padded_packed, conv_padded_tiled, gemm_tiled, PackedA, Scratch};
use cocoi::conv::im2col;
use cocoi::conv::{ConvSpec, Tensor};
use cocoi::runtime::{ConvProvider, FallbackProvider};
use cocoi::util::{prop, Rng};

/// f64-accumulated reference — tighter than either f32 path, so both can
/// be compared against it with a common tolerance.
fn gemm_f64(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for l in 0..kk {
                acc += a[i * kk + l] as f64 * b[l * n + j] as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

fn random_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_uniform_f32(&mut v, -1.0, 1.0);
    v
}

#[test]
fn tiled_matches_scalar_oracle_across_shapes() {
    prop::check("tiled == oracle", 40, |rng| {
        let m = 1 + rng.below(70); // crosses MR=4 remainders
        let kk = 1 + rng.below(600); // crosses the KC=256 slab boundary
        let n = 1 + rng.below(300); // crosses NR=8 remainders
        let a = random_mat(rng, m * kk);
        let b = random_mat(rng, kk * n);
        let tiled = gemm_tiled(&a, m, kk, &b, n, 1 + rng.below(4));
        let scalar = im2col::gemm(&a, m, kk, &b, n);
        let oracle = gemm_f64(&a, m, kk, &b, n);
        let tol = 1e-5 * (kk as f32).max(16.0);
        for ((t, s), o) in tiled.iter().zip(&scalar).zip(&oracle) {
            assert!((t - o).abs() < tol, "tiled {t} vs f64 {o} (m={m} kk={kk} n={n})");
            assert!((s - o).abs() < tol, "scalar {s} vs f64 {o} (m={m} kk={kk} n={n})");
        }
    });
}

#[test]
fn tall_and_skinny_extremes() {
    let mut rng = Rng::new(0x7A11);
    // (1×k)·(k×1), single-column, single-row, and panel-boundary shapes.
    for (m, kk, n) in [
        (1, 1000, 1),
        (1000, 3, 2),
        (2, 5, 1000),
        (4, 256, 8),
        (5, 257, 9),
        (8, 512, 16),
    ] {
        let a = random_mat(&mut rng, m * kk);
        let b = random_mat(&mut rng, kk * n);
        let tiled = gemm_tiled(&a, m, kk, &b, n, 4);
        let oracle = gemm_f64(&a, m, kk, &b, n);
        let tol = 1e-5 * (kk as f32).max(16.0);
        for (t, o) in tiled.iter().zip(&oracle) {
            assert!((t - o).abs() < tol, "m={m} kk={kk} n={n}");
        }
    }
}

#[test]
fn bitwise_identical_across_1_2_4_threads() {
    let mut rng = Rng::new(0xB17);
    // Shapes chosen to clear the parallelism FLOP gate with remainders
    // on every axis; plus one tiny shape that stays sequential.
    for (m, kk, n) in [(64, 576, 784), (33, 300, 523), (7, 9, 11)] {
        let a = random_mat(&mut rng, m * kk);
        let b = random_mat(&mut rng, kk * n);
        let c1 = gemm_tiled(&a, m, kk, &b, n, 1);
        let c2 = gemm_tiled(&a, m, kk, &b, n, 2);
        let c4 = gemm_tiled(&a, m, kk, &b, n, 4);
        assert_eq!(c1, c2, "1 vs 2 threads (m={m} kk={kk} n={n})");
        assert_eq!(c1, c4, "1 vs 4 threads (m={m} kk={kk} n={n})");
    }
}

#[test]
fn conv_paths_agree_and_scratch_reuse_is_stable() {
    let mut rng = Rng::new(0xC0);
    let spec = ConvSpec::new(16, 24, 3, 1, 0);
    let mut input = Tensor::zeros(16, 30, 28);
    rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let w = random_mat(&mut rng, spec.weight_len());

    let provider = FallbackProvider::with_threads(2);
    let plain = provider.conv(&spec, &input, &w).unwrap();

    let mut scratch = Scratch::new();
    let tiled = conv_padded_tiled(&spec, &input, &w, 2, &mut scratch).unwrap();
    let packed = provider.prepack(&spec, &w).unwrap();
    let prepacked = conv_padded_packed(&spec, &input, &packed, 2, &mut scratch).unwrap();
    assert_eq!(plain.data, tiled.data);
    assert_eq!(plain.data, prepacked.data);

    // Dirty the scratch with a different geometry, then repeat: reuse
    // must not perturb a single bit.
    let other = ConvSpec::new(3, 5, 5, 2, 0);
    let mut oin = Tensor::zeros(3, 40, 33);
    rng.fill_uniform_f32(&mut oin.data, -1.0, 1.0);
    let ow = random_mat(&mut rng, other.weight_len());
    conv_padded_tiled(&other, &oin, &ow, 2, &mut scratch).unwrap();
    let again = conv_padded_packed(&spec, &input, &packed, 2, &mut scratch).unwrap();
    assert_eq!(plain.data, again.data);

    // And the whole thing stays within fp tolerance of the scalar oracle.
    let oracle = spec.conv_padded(&input, &w).unwrap();
    assert!(plain.max_abs_diff(&oracle) < 1e-3);
}

#[test]
fn one_by_one_conv_uses_identity_im2col() {
    let mut rng = Rng::new(0x11);
    let spec = ConvSpec::new(8, 12, 1, 1, 0);
    let mut input = Tensor::zeros(8, 17, 13);
    rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
    // The 1×1 stride-1 patch matrix is exactly the flattened input.
    assert_eq!(im2col::im2col(&input, 1, 1), input.data);
    let w = random_mat(&mut rng, spec.weight_len());
    let mut scratch = Scratch::new();
    let fast = conv_padded_tiled(&spec, &input, &w, 2, &mut scratch).unwrap();
    let oracle = spec.conv_padded(&input, &w).unwrap();
    assert!(fast.max_abs_diff(&oracle) < 1e-3);
}

#[test]
fn packed_weights_shape_mismatch_rejected() {
    let mut rng = Rng::new(77);
    let spec = ConvSpec::new(4, 6, 3, 1, 0);
    let w = random_mat(&mut rng, spec.weight_len());
    let pa = PackedA::pack(&w, spec.c_out, spec.c_in * 9);
    assert_eq!(pa.m(), 6);
    assert_eq!(pa.k(), 36);
    let mut input = Tensor::zeros(4, 8, 8);
    rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let other = ConvSpec::new(4, 7, 3, 1, 0);
    let mut scratch = Scratch::new();
    assert!(conv_padded_packed(&other, &input, &pa, 1, &mut scratch).is_err());
}
