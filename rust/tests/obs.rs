//! Observability suite: the span recorder, histograms, and scrape
//! surface wired through the REAL serving stack.
//!
//! Pinned here:
//! * a fault-injected traced run produces well-formed span trees (no
//!   invariant violations, every delivered tree closed) whose hedge
//!   events carry nonzero win/loss latencies consistent with the
//!   per-request `InferenceMetrics` and the hub histograms;
//! * tracing off (the default) allocates ZERO spans and the outputs are
//!   bitwise identical with tracing on — observability never perturbs
//!   the numerics;
//! * `InferenceServer::scrape` passes the hard Prometheus schema check
//!   with the full stable family set;
//! * the histogram quantile estimate honours its documented ~4.4%
//!   relative-error bound against exact order statistics, and merging
//!   two histograms equals the histogram of the concatenated samples.
//!
//! Tests that record spans serialize on a file-local gate: the
//! allocation counter is process-global, so the zero-alloc delta must
//! not race another test's traced run.

use std::sync::{mpsc, Arc, Mutex, MutexGuard, OnceLock};

use cocoi::conv::{ConvSpec, Tensor};
use cocoi::coordinator::{
    run_worker_announcing, ExecMode, InferenceRequest, InferenceServer, JoinOptions, LocalCluster,
    Master, MasterConfig, PoolOptions, SchemeKind, ServerConfig, WorkerConfig, WorkerExit,
    WorkerFaults, WorkerHandles,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::obs::export::check_exposition;
use cocoi::obs::hist::{quantile_error_bound, LogHistogram};
use cocoi::obs::trace::{spans_allocated, TraceHandle};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::{ConvProvider, FallbackProvider};
use cocoi::transport::split::split_tcp;
use cocoi::util::json::Json;
use cocoi::util::Rng;

fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(Mutex::default)
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn inputs_for(count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

/// Uncoded n=3 with worker 0 stalling forever: every round needs the
/// watchdog hedge, and uncoded shards stay bitwise-reproducible on any
/// worker — the sharpest fixture for tracing under faults.
fn spawn_stalled(trace: Option<TraceHandle>) -> (InferenceServer, WorkerHandles) {
    let mut faults: Vec<WorkerFaults> = (0..3).map(|_| WorkerFaults::none()).collect();
    faults[0] = WorkerFaults::none().stalls_in(0..4096);
    let config = MasterConfig {
        scheme: SchemeKind::Uncoded,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::Pipelined,
        trace,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn_with(
        "tinyvgg",
        3,
        config,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots: 1 },
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    (InferenceServer::start(master, ServerConfig::default()), workers)
}

/// Fault-injected traced run: well-formed trees, hedge events with
/// nonzero latencies, and agreement between the trace, the per-request
/// metrics, and the hub histograms.
#[test]
fn traced_stalled_run_has_wellformed_trees_and_hedge_latencies() {
    let _g = gate();
    let inputs = inputs_for(3, 930);
    let want = local_refs(&inputs);
    let trace = TraceHandle::new(16_384);
    let (server, workers) = spawn_stalled(Some(trace.clone()));
    let pre = server.scrape(); // pre-run scrape must already be schema-clean
    assert!(check_exposition(&pre.to_prometheus()).unwrap() >= 24);

    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    let mut total_hedges = 0u64;
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, m) = h.wait().expect("traced request wedged");
        assert_eq!(out.data, want.data, "traced hedged output not bitwise-local");
        assert!(m.hedges() >= 1, "stalled worker never hedged");
        total_hedges += m.hedges() as u64;
    }
    let scrape = server.scrape();
    let master = server.shutdown().unwrap();
    let hub = master.metrics_hub().snapshot();
    master.shutdown();
    workers.join().unwrap();

    let viol = trace.violations();
    assert!(viol.is_empty(), "trace invariant violations: {viol:?}");
    let reqs = trace.requests();
    assert_eq!(reqs.len(), inputs.len());
    let (mut fired, mut outcomes) = (0u64, 0u64);
    for rt in &reqs {
        assert!(rt.done, "request {} tree still open", rt.request);
        assert_eq!(rt.open_spans(), 0, "request {} has open spans", rt.request);
        for name in ["request", "queue-wait"] {
            assert!(
                rt.spans.iter().any(|s| s.name == name),
                "request {} missing '{name}' span",
                rt.request
            );
        }
        assert!(rt.spans.iter().any(|s| s.name.starts_with("round:")));
        assert!(rt.spans.iter().any(|s| s.name.starts_with("task:")));
        for e in &rt.events {
            match e.name.as_str() {
                "hedge-fired" => fired += 1,
                "hedge-won" | "hedge-lost" => {
                    outcomes += 1;
                    let v = e.value.expect("hedge outcome event carries a latency");
                    assert!(v.is_finite() && v > 0.0, "hedge latency {v} not positive");
                }
                _ => {}
            }
        }
    }
    assert_eq!(
        fired, total_hedges,
        "traced hedge-fired events disagree with InferenceMetrics::hedges()"
    );
    assert!(outcomes >= 1, "no hedge outcome event was traced");
    // The hub saw exactly the traced outcomes, with positive latencies.
    assert_eq!(hub.hedge_win.count() + hub.hedge_loss.count(), outcomes);
    if hub.hedge_win.count() > 0 {
        assert!(hub.hedge_win.quantile(0.5) > 0.0);
    }
    // The final scrape reflects the served requests.
    let j = scrape.to_json();
    assert_eq!(
        j.get("counters").req_f64("cocoi_server_completed_total").unwrap(),
        inputs.len() as f64
    );
    assert_eq!(
        j.get("histograms").get("cocoi_sojourn_seconds").req_f64("count").unwrap(),
        inputs.len() as f64
    );

    // Chrome export round-trips through the JSON parser and carries the
    // request tracks.
    let back = Json::parse(&trace.export_chrome().to_string_pretty()).unwrap();
    let evs = back.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(evs.len() > 10, "suspiciously small trace: {} events", evs.len());
    assert!(evs
        .iter()
        .any(|e| e.get("ph").as_str() == Some("X") && e.get("name").as_str() == Some("request")));
    let text = trace.export_text();
    assert!(text.contains("queue-wait"));
}

/// Zero-cost-off: the identical fault-injected workload with
/// `trace: None` allocates not a single span, and its outputs are
/// bitwise identical to the traced run's.
#[test]
fn tracing_off_allocates_nothing_and_matches_traced_outputs() {
    let _g = gate();
    let inputs = inputs_for(2, 931);
    let want = local_refs(&inputs);
    let run = |trace: Option<TraceHandle>| -> Vec<Tensor> {
        let (server, workers) = spawn_stalled(trace);
        let handles: Vec<_> = inputs
            .iter()
            .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
            .collect();
        let outs = handles
            .into_iter()
            .map(|h| h.wait().expect("request wedged").0)
            .collect();
        let master = server.shutdown().unwrap();
        master.shutdown();
        workers.join().unwrap();
        outs
    };

    let before = spans_allocated();
    let untraced = run(None);
    assert_eq!(
        spans_allocated(),
        before,
        "tracing off must allocate zero spans"
    );

    let trace = TraceHandle::new(4096);
    let traced = run(Some(trace.clone()));
    assert!(spans_allocated() > before, "traced run recorded nothing");
    assert!(trace.violations().is_empty(), "{:?}", trace.violations());

    for ((a, b), w) in untraced.iter().zip(&traced).zip(&want) {
        assert_eq!(a.data, b.data, "tracing changed the output bytes");
        assert_eq!(a.data, w.data, "run diverged from local inference");
    }
}

/// [`ConvProvider`] that signals the test thread on every conv call —
/// the join probe runs post-admission, so the first signal means "this
/// wire worker is in the dispatch set".
struct SignalProvider {
    inner: FallbackProvider,
    tx: Mutex<mpsc::Sender<()>>,
}

impl SignalProvider {
    fn new() -> (Arc<SignalProvider>, mpsc::Receiver<()>) {
        let (tx, rx) = mpsc::channel();
        (
            Arc::new(SignalProvider {
                inner: FallbackProvider::new(),
                tx: Mutex::new(tx),
            }),
            rx,
        )
    }
}

impl ConvProvider for SignalProvider {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> anyhow::Result<Tensor> {
        let _ = self.tx.lock().unwrap().send(());
        self.inner.conv(spec, input, weights)
    }

    fn name(&self) -> &'static str {
        "signal"
    }
}

/// Satellite of the scheme-selection PR: `--trace-sample N` records one
/// request tree in every N. The pin is on the WIRE deployment shape —
/// remote workers have no recorder handle, so the span-allocation
/// counter measures exactly the engine's per-request emits — and a
/// sampled-out request must cost ZERO span allocations end to end:
/// admission leaves its `root_span` as `None`, and every round/task/
/// hedge/retry/fallback emit site gates on that. (In-proc `LocalCluster`
/// pools still record bounded pool-level slot spans; those are per-slot
/// observability, not part of any request tree.)
#[test]
fn trace_sampling_records_one_in_n_with_zero_spans_for_the_rest() {
    let _g = gate();
    let inputs = inputs_for(3, 933);
    let want = local_refs(&inputs);
    let trace = TraceHandle::new(8192);
    let config = MasterConfig {
        scheme: SchemeKind::Uncoded,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::Pipelined,
        trace: Some(trace.clone()),
        trace_sample: 3, // requests 1, 4, 7, … get a tree
        ..Default::default()
    };
    let mut master =
        Master::new_elastic("tinyvgg", config, 3, Arc::new(FallbackProvider::new())).unwrap();
    let addr = master.listen("127.0.0.1:0").unwrap();
    let server = InferenceServer::start(master, ServerConfig::default());

    let mut members = Vec::new();
    for name in ["wire-a", "wire-b"] {
        let (provider, probed) = SignalProvider::new();
        let stream = std::net::TcpStream::connect(addr).unwrap();
        let name = name.to_string();
        members.push(
            std::thread::Builder::new()
                .name(format!("member-{name}"))
                .spawn(move || {
                    let (tx, rx) = split_tcp(stream)?;
                    run_worker_announcing(
                        Box::new(tx),
                        Box::new(rx),
                        WorkerConfig {
                            id: 0, // reassigned from JoinAck
                            provider,
                            faults: WorkerFaults::none(),
                            rng_seed: 0xABCD,
                            slots: 1,
                            trace: None, // wire workers share no recorder
                        },
                        &JoinOptions {
                            name,
                            model: String::new(),
                        },
                    )
                })
                .unwrap(),
        );
        probed
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("wire worker never probed");
    }

    // Request 1 is the 1-in-N sample; wait for it so its tree is closed
    // before measuring the sampled-out delta.
    let (out0, m0) = server
        .submit(InferenceRequest::new(inputs[0].clone()))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out0.data, want[0].data, "uncoded run not bitwise-local");
    assert!(m0.layers.iter().any(|l| l.distributed));
    let after_sampled = spans_allocated();

    for (inp, w) in inputs.iter().zip(&want).skip(1) {
        let (out, m) = server
            .submit(InferenceRequest::new(inp.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.data, w.data, "sampling changed the output bytes");
        assert!(
            m.layers.iter().any(|l| l.distributed),
            "sampled-out request must still distribute"
        );
    }
    assert_eq!(
        spans_allocated(),
        after_sampled,
        "sampled-out requests must allocate zero spans"
    );

    let master = server.shutdown().unwrap();
    master.shutdown();
    for h in members {
        assert_eq!(h.join().unwrap().unwrap(), WorkerExit::Shutdown);
    }

    assert!(trace.violations().is_empty(), "{:?}", trace.violations());
    let reqs = trace.requests();
    assert_eq!(reqs.len(), 1, "exactly the 1-in-N request is recorded");
    assert!(reqs[0].done, "sampled tree left open");
    assert_eq!(reqs[0].open_spans(), 0);
    assert!(reqs[0].spans.iter().any(|s| s.name.starts_with("round:")));
}

/// A healthy pool's scrape: full stable family set, hard schema check,
/// and counters that add up.
#[test]
fn server_scrape_passes_schema_check_with_stable_families() {
    let inputs = inputs_for(3, 932);
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(2),
        mode: ExecMode::Pipelined,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn_with(
        "tinyvgg",
        3,
        config,
        Arc::new(FallbackProvider::new()),
        (0..3).map(|_| WorkerFaults::none()).collect(),
        PoolOptions { worker_slots: 1 },
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    let server = InferenceServer::start(master, ServerConfig::default());
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }

    let snap = server.scrape();
    let text = snap.to_prometheus();
    // 6 server families + 19 hub families + 5 tenant-labelled families
    // (requests flowed under the default tenant), every one schema-clean.
    assert_eq!(check_exposition(&text).unwrap(), 30);
    assert!(text.contains("cocoi_server_submitted_total 3"));
    assert!(text.contains("cocoi_server_completed_total 3"));
    assert!(text.contains("cocoi_server_open_requests 0"));
    assert!(text.contains("# TYPE cocoi_sojourn_seconds histogram"));
    assert!(text.contains("cocoi_hedges_total 0"));
    // Family order is stable scrape over scrape.
    assert_eq!(snap.family_names(), server.scrape().family_names());

    let master = server.shutdown().unwrap();
    master.shutdown();
    workers.join().unwrap();
}

/// The quantile estimate stays within the documented relative-error
/// bound of the exact order statistic at the same rank.
#[test]
fn histogram_quantile_honours_documented_error_bound() {
    let mut rng = Rng::new(77);
    let mut h = LogHistogram::new();
    // Latencies spread over ~4 decades, the regime the log buckets target.
    let mut vals: Vec<f64> = (0..20_000)
        .map(|_| 1e-4 * (9.0 * rng.uniform()).exp())
        .collect();
    for &v in &vals {
        h.record(v);
    }
    vals.sort_by(f64::total_cmp);
    let bound = quantile_error_bound() + 1e-12;
    for q in [0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
        let rank = ((q * vals.len() as f64).ceil() as usize).max(1);
        let exact = vals[rank - 1];
        let est = h.quantile(q);
        let rel = (est - exact).abs() / exact;
        assert!(
            rel <= bound,
            "q={q}: estimate {est} vs exact {exact} → rel err {rel:.4} > {bound:.4}"
        );
    }
}

/// merge(a, b) is exactly the histogram of the concatenated samples —
/// identical buckets, count, sum, min/max, and therefore quantiles.
#[test]
fn histogram_merge_equals_concatenation() {
    let mut rng = Rng::new(88);
    let (mut a, mut b, mut all) = (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
    for i in 0..5_000 {
        let v = 1e-5 * (10.0 * rng.uniform()).exp();
        all.record(v);
        if i % 2 == 0 {
            a.record(v);
        } else {
            b.record(v);
        }
    }
    a.merge(&b);
    assert_eq!(a.count(), all.count());
    assert!((a.sum() - all.sum()).abs() <= 1e-9 * all.sum());
    assert_eq!(a.min().to_bits(), all.min().to_bits());
    assert_eq!(a.max().to_bits(), all.max().to_bits());
    assert_eq!(a.cumulative_buckets(), all.cumulative_buckets());
    for q in [0.1, 0.5, 0.9, 0.99] {
        assert_eq!(a.quantile(q).to_bits(), all.quantile(q).to_bits());
    }
}
