//! Scheme-selection suite: the rateless LT path and the per-layer
//! selector exercised at the WIRE — real `LocalCluster` pools, real
//! dispatch frames, real decoders — plus the coding-layer any-k
//! property and the deadline-redundancy rule through the public API.
//!
//! Pinned here:
//! * LT any-k completion is order-independent: the exact symbol subset
//!   that first reaches rank `k` decodes identically under any arrival
//!   permutation — the property that lets the engine finish a round on
//!   whatever useful symbols land first;
//! * `--scheme uncoded` at the wire is a bitwise-local oracle, and the
//!   coded schemes (`mds`, `lt`, `auto`) stay within the 2e-2 float
//!   tolerance of local inference;
//! * an LT round with a forever-stalling worker completes from the
//!   healthy workers' symbols with ZERO re-dispatches — any-k
//!   completion on the real reply path, not just in the decoder;
//! * the deadline rule (`solve_deadline_k`) is monotone: tighter slack
//!   never *raises* k, and the chosen split's tail quantile fits.

use std::sync::Arc;

use cocoi::conv::{ConvSpec, Tensor};
use cocoi::coding::select::{lt_budget, lt_symbols_needed};
use cocoi::coding::{Decoder, LtCode, RedundancyScheme, SchemeKind, SchemeSelector};
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, PoolOptions,
    ServerConfig, WorkerFaults, WorkerHandles,
};
use cocoi::latency::approx::l_tail_quantile;
use cocoi::latency::phases::LayerDims;
use cocoi::latency::SystemProfile;
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::deadline::solve_deadline_k;
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::{prop, Rng};

// ---------------------------------------------------------------- coding

/// Any-k, order-independent: run the decoder over a random arrival
/// permutation to find the first useful subset, then re-feed exactly
/// that subset under fresh shuffles — rank is a property of the SET of
/// symbols, so every order must decode to the same sources.
#[test]
fn lt_useful_subset_decodes_under_any_arrival_order() {
    prop::check("lt any-k order independence", 24, |rng| {
        let n = 2 + rng.below(6); // 2..=7 "workers" (reporting only)
        let k = 1 + rng.below(10); // 1..=10 source partitions
        let len = 1 + rng.below(48);
        let code = LtCode::new(n, k, rng.next_u64());
        let sources: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..len).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
            .collect();
        let tasks = code.encode(&sources);
        assert_eq!(tasks.len(), lt_budget(k), "budget helper out of sync");

        // First pass: discover the useful subset under one arrival order.
        let mut order: Vec<usize> = (0..tasks.len()).collect();
        rng.shuffle(&mut order);
        let mut dec = code.decoder();
        let mut useful: Vec<usize> = Vec::new();
        for &t in &order {
            useful.push(t);
            if dec.add(tasks[t].id, tasks[t].payload.clone()) {
                break;
            }
        }
        assert!(dec.ready(), "k={k}: budget {} never reached rank", tasks.len());
        let want = dec.decode().unwrap();
        for (w, s) in want.iter().zip(&sources) {
            for (a, b) in w.iter().zip(s) {
                assert!((a - b).abs() < 1e-3, "identity decode off: {a} vs {b}");
            }
        }

        // Re-feed ONLY that subset in fresh random orders: same decode.
        for _ in 0..3 {
            rng.shuffle(&mut useful);
            let mut dec = code.decoder();
            for &t in &useful {
                dec.add(tasks[t].id, tasks[t].payload.clone());
            }
            assert!(dec.ready(), "useful subset lost rank under reordering");
            let again = dec.decode().unwrap();
            for (a_row, b_row) in again.iter().zip(&want) {
                for (a, b) in a_row.iter().zip(b_row) {
                    assert!((a - b).abs() < 1e-3, "arrival order changed the decode");
                }
            }
        }
    });
}

/// The selector's symbol-count model brackets reality: the decoder's
/// measured need sits at or above `k`, and within the dispatch budget
/// for the split sizes the engine actually uses.
#[test]
fn lt_overhead_model_brackets_measured_need() {
    let mut rng = Rng::new(0x5E1EC7);
    for k in [1usize, 2, 3, 5, 8, 13] {
        for trial in 0..8u64 {
            let code = LtCode::new(4, k, 0xC0DE + 31 * trial + k as u64);
            let sources: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..8).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
                .collect();
            let tasks = code.encode(&sources);
            let mut dec = code.decoder();
            let mut needed = tasks.len();
            for (used, t) in tasks.iter().enumerate() {
                if dec.add(t.id, t.payload.clone()) {
                    needed = used + 1;
                    break;
                }
            }
            assert!(dec.ready(), "k={k} trial={trial}: rank never reached");
            assert!(needed >= k, "decoded below the information bound");
            assert!(
                needed <= lt_budget(k),
                "k={k}: needed {needed} > budget {}",
                lt_budget(k)
            );
        }
        assert!(
            lt_symbols_needed(k) >= k && lt_symbols_needed(k) <= lt_budget(k),
            "k={k}: selector estimate outside [k, budget]"
        );
    }
}

// ------------------------------------------------------------------ wire

fn cluster_with(
    scheme: SchemeKind,
    faults: Vec<WorkerFaults>,
) -> (InferenceServer, WorkerHandles) {
    let n = faults.len();
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::Pipelined,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn_with(
        "tinyvgg",
        n,
        config,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots: 1 },
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    (InferenceServer::start(master, ServerConfig::default()), workers)
}

fn inputs_for(count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn run_requests(server: &InferenceServer, inputs: &[Tensor]) -> Vec<(Tensor, usize)> {
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    handles
        .into_iter()
        .map(|h| {
            let (out, m) = h.wait().expect("request wedged");
            assert!(
                m.layers.iter().any(|l| l.distributed),
                "pool never distributed a layer"
            );
            (out, m.redispatches())
        })
        .collect()
}

/// `--scheme uncoded` is the bitwise oracle: every shard is a verbatim
/// input slice, so the wire output must equal local inference byte for
/// byte. The coded schemes ride the same dispatch path and must land
/// within the float-GE tolerance.
#[test]
fn wire_uncoded_is_bitwise_and_coded_schemes_are_close() {
    let inputs = inputs_for(2, 1201);
    let want = local_refs(&inputs);

    let (server, workers) = cluster_with(SchemeKind::Uncoded, vec![WorkerFaults::none(); 3]);
    for ((out, _), w) in run_requests(&server, &inputs).iter().zip(&want) {
        assert_eq!(out.data, w.data, "uncoded wire run not bitwise-local");
    }
    server.shutdown().unwrap().shutdown();
    workers.join().unwrap();

    for scheme in [SchemeKind::Mds, SchemeKind::LtCoarse, SchemeKind::Auto] {
        let (server, workers) = cluster_with(scheme, vec![WorkerFaults::none(); 3]);
        for ((out, _), w) in run_requests(&server, &inputs).iter().zip(&want) {
            let err = out.max_abs_diff(w);
            assert!(err < 2e-2, "{scheme:?}: wire output off local by {err}");
        }
        server.shutdown().unwrap().shutdown();
        workers.join().unwrap();
    }
}

/// Any-k completion on the real reply path: with one worker stalling
/// forever, an LT round must finish from the healthy workers' symbol
/// share alone — no `Failed` replies, no eviction, and therefore ZERO
/// re-dispatches. (Under MDS at n = k the same fixture needs the
/// watchdog; rateless redundancy absorbs the straggler by design.)
#[test]
fn wire_lt_round_completes_from_healthy_symbol_share_without_redispatch() {
    let mut faults = vec![WorkerFaults::none(); 3];
    faults[0] = WorkerFaults::none().stalls_in(0..4096);
    let inputs = inputs_for(2, 1301);
    let want = local_refs(&inputs);

    let (server, workers) = cluster_with(SchemeKind::LtCoarse, faults);
    for ((out, redispatches), w) in run_requests(&server, &inputs).iter().zip(&want) {
        let err = out.max_abs_diff(w);
        assert!(err < 2e-2, "lt wire output off local by {err}");
        assert_eq!(
            *redispatches, 0,
            "rateless round must absorb the straggler without re-dispatch"
        );
    }
    let master = server.shutdown().unwrap();
    let json = master.telemetry_json().to_string();
    assert!(json.contains("ltcoi-ks"), "plan scheme missing from telemetry: {json}");
    master.shutdown();
    workers.join().unwrap();
}

/// `--scheme auto` on a calm pool resolves every distributed layer to
/// the concrete MDS default (the selector's calm arm) — visible in the
/// telemetry plan dump — and serves correct outputs.
#[test]
fn wire_auto_resolves_to_concrete_schemes_on_calm_pool() {
    let inputs = inputs_for(1, 1401);
    let want = local_refs(&inputs);

    let (server, workers) = cluster_with(SchemeKind::Auto, vec![WorkerFaults::none(); 3]);
    for ((out, _), w) in run_requests(&server, &inputs).iter().zip(&want) {
        let err = out.max_abs_diff(w);
        assert!(err < 2e-2, "auto wire output off local by {err}");
    }
    let master = server.shutdown().unwrap();
    let json = master.telemetry_json().to_string();
    assert!(
        json.contains("cocoi-mds"),
        "auto plan should seed concrete MDS on a calm pool: {json}"
    );
    assert!(
        !json.contains("\"scheme\":\"auto\""),
        "auto must never reach a dispatched plan unresolved: {json}"
    );
    master.shutdown();
    workers.join().unwrap();
}

// -------------------------------------------------------------- deadline

/// Dutta-style deadline redundancy through the public API: shrinking
/// slack never raises k, every accepted split's tail quantile fits the
/// slack it was solved for, and impossible slack returns `None` (the
/// selector's LT flip).
#[test]
fn deadline_rule_is_monotone_and_tail_feasible() {
    let p = SystemProfile::paper_default();
    let dims = LayerDims::new(ConvSpec::new(64, 64, 3, 1, 1), 56, 56);
    let (n, k_base, z) = (8, 6, 1.65);
    let roomy = l_tail_quantile(&dims, &p, n, k_base, z) * 4.0;
    let mut prev_k = usize::MAX;
    let mut saw_some = false;
    let mut saw_none = false;
    for step in 0..40 {
        let slack = roomy * (1.0 - step as f64 / 40.0);
        match solve_deadline_k(&dims, &p, n, k_base, slack, z) {
            Some(kd) => {
                saw_some = true;
                assert!(kd >= 1 && kd <= k_base, "kd={kd} outside [1, {k_base}]");
                assert!(
                    kd <= prev_k,
                    "tighter slack raised k: {kd} after {prev_k}"
                );
                let tail = l_tail_quantile(&dims, &p, n, kd, z);
                assert!(
                    tail <= slack * (1.0 + 1e-9),
                    "chosen k={kd} tail {tail} misses slack {slack}"
                );
                prev_k = kd;
            }
            None => saw_none = true,
        }
    }
    assert!(saw_some, "roomy slack should admit a split");
    assert!(saw_none, "near-zero slack should reject every split");
    // And the selector flips those rejections to rateless.
    let sel = SchemeSelector::default();
    let c = sel.choose(&dims, &p, n, k_base, Some(1e-12), 0);
    assert_eq!(c.kind, SchemeKind::LtCoarse, "impossible deadline must go rateless");
}
