//! Edge-case and failure-injection coverage beyond the per-module suites:
//! extreme split choices, remainder pieces, maximum tolerable failures,
//! fuzzed wire inputs, and straggler wall-clock effects.

use std::sync::Arc;

use cocoi::conv::{ConvSpec, SplitPlan, Tensor};
use cocoi::coordinator::{
    LocalCluster, MasterConfig, SchemeKind, WorkerFaults,
};
use cocoi::coordinator::messages::{FromWorker, ToWorker};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::json::Json;
use cocoi::util::Rng;

fn reference(model_name: &str, seed: u64) -> (Tensor, Tensor) {
    let model = zoo::model(model_name).unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let mut input = Tensor::zeros(model.input.0, model.input.1, model.input.2);
    Rng::new(seed).fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let out = forward_local(&model, &weights, &input).unwrap();
    (input, out)
}

fn run(
    model_name: &str,
    scheme: SchemeKind,
    n: usize,
    k: usize,
    faults: Vec<WorkerFaults>,
    input: &Tensor,
) -> (Tensor, cocoi::coordinator::InferenceMetrics) {
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        ..Default::default()
    };
    let mut cluster =
        LocalCluster::spawn(model_name, n, config, Arc::new(FallbackProvider::new()), faults)
            .unwrap();
    let result = cluster.master.infer(input).unwrap();
    cluster.shutdown().unwrap();
    result
}

/// k = 1: every worker convolves the whole input; any single completion
/// decodes (full redundancy).
#[test]
fn k_equals_one_full_redundancy() {
    let (input, want) = reference("tinyvgg", 41);
    let faults = vec![
        WorkerFaults::none().fails_in(0..64),
        WorkerFaults::none().fails_in(0..64),
        WorkerFaults::none(), // only worker 2 alive
    ];
    let (got, metrics) = run("tinyvgg", SchemeKind::Mds, 3, 1, faults, &input);
    assert!(got.max_abs_diff(&want) < 2e-2);
    // The healthy worker's output may win the race before the failure
    // signals arrive, so `failures()` can legitimately read 0 — the
    // invariant is zero re-dispatch and a correct answer.
    assert_eq!(metrics.redispatches(), 0, "k=1 tolerates n-1 failures");
}

/// Maximum tolerable simultaneous failures: n − k workers dead forever.
#[test]
fn exactly_r_failures_absorbed() {
    let (input, want) = reference("tinyvgg", 43);
    let n = 5;
    let k = 2; // r = 3
    let faults: Vec<WorkerFaults> = (0..n)
        .map(|i| {
            if i < 3 {
                WorkerFaults::none().fails_in(0..64)
            } else {
                WorkerFaults::none()
            }
        })
        .collect();
    let (got, metrics) = run("tinyvgg", SchemeKind::Mds, n, k, faults, &input);
    assert!(got.max_abs_diff(&want) < 2e-2);
    assert_eq!(metrics.redispatches(), 0, "r = 3 absorbs 3 failures");
}

/// One more failure than redundancy: the master must re-dispatch and
/// still produce the right answer.
#[test]
fn r_plus_one_failures_force_redispatch() {
    let (input, want) = reference("tinyvgg", 47);
    let n = 4;
    let k = 3; // r = 1, two failing workers
    let faults: Vec<WorkerFaults> = (0..n)
        .map(|i| {
            if i < 2 {
                WorkerFaults::none().fails_in(0..2) // fail only first rounds
            } else {
                WorkerFaults::none()
            }
        })
        .collect();
    let (got, metrics) = run("tinyvgg", SchemeKind::Mds, n, k, faults, &input);
    assert!(got.max_abs_diff(&want) < 2e-2);
    assert!(metrics.redispatches() > 0, "must have re-dispatched");
}

/// Remainder handling: k that does not divide W_O exercises the
/// master-local remainder piece (footnote 2).
#[test]
fn remainder_piece_correct() {
    // tinyvgg conv5/conv6 have W_O = 14; k = 4 leaves remainder 2.
    let (input, want) = reference("tinyvgg", 53);
    let (got, _) = run(
        "tinyvgg",
        SchemeKind::Mds,
        5,
        4,
        (0..5).map(|_| WorkerFaults::none()).collect(),
        &input,
    );
    assert!(got.max_abs_diff(&want) < 2e-2);
    // Geometry-level check too.
    let spec = ConvSpec::new(1, 1, 3, 1, 1);
    let plan = SplitPlan::new(&spec, 16, 3).unwrap(); // W_O = 14, k = 3
    let rem = plan.remainder_out.expect("14 % 3 != 0");
    assert_eq!(rem.width(), 14 % 3);
}

/// LT coding under failures: rateless redundancy absorbs a dead worker.
#[test]
fn lt_survives_failure() {
    let (input, want) = reference("tinyvgg", 59);
    let n = 4;
    let faults: Vec<WorkerFaults> = (0..n)
        .map(|i| {
            if i == 0 {
                WorkerFaults::none().fails_in(0..64)
            } else {
                WorkerFaults::none()
            }
        })
        .collect();
    let (got, metrics) = run("tinyvgg", SchemeKind::LtCoarse, n, 3, faults, &input);
    assert!(got.max_abs_diff(&want) < 2e-2);
    assert!(metrics.failures() > 0);
}

/// Chronic straggler slows the straggler path but never corrupts output.
#[test]
fn chronic_straggler_correctness() {
    let (input, want) = reference("tinyresnet", 61);
    let n = 4;
    let mut faults: Vec<WorkerFaults> = (0..n).map(|_| WorkerFaults::none()).collect();
    faults[0] = WorkerFaults::none().slowdown(3.0);
    let (got, _) = run("tinyresnet", SchemeKind::Mds, n, 3, faults, &input);
    assert!(got.max_abs_diff(&want) < 2e-2);
}

/// Wire-format fuzz: random bytes must error, never panic.
#[test]
fn message_decode_fuzz() {
    let mut rng = Rng::new(0xF422);
    for _ in 0..2000 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = ToWorker::decode(&bytes); // Result either way; no panic
        let _ = FromWorker::decode(&bytes);
    }
}

/// JSON parser fuzz: random printable garbage must error, never panic.
#[test]
fn json_parse_fuzz() {
    let mut rng = Rng::new(0xF423);
    let alphabet: Vec<char> = r#"{}[]",:0123456789.eE+-truefalsnl \u"#.chars().collect();
    for _ in 0..2000 {
        let len = rng.below(40);
        let s: String = (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
        let _ = Json::parse(&s);
    }
}

/// Tensors with w == kernel width (minimum splittable geometry).
#[test]
fn minimum_width_layers() {
    let spec = ConvSpec::new(2, 3, 3, 1, 0);
    let plan = SplitPlan::new(&spec, 3, 1).unwrap(); // W_O = 1, only k = 1
    assert_eq!(plan.w_o, 1);
    assert_eq!(plan.w_i_p, 3);
    assert!(SplitPlan::new(&spec, 3, 2).is_err());
}

/// Scenario-1 injection measurably delays real execution.
#[test]
fn straggler_injection_costs_wall_clock() {
    let (input, _) = reference("tinyvgg", 67);
    let t = |faults: Vec<WorkerFaults>| {
        let t0 = std::time::Instant::now();
        let _ = run("tinyvgg", SchemeKind::Uncoded, 3, 3, faults, &input);
        t0.elapsed().as_secs_f64()
    };
    let fast = t((0..3).map(|_| WorkerFaults::none()).collect());
    // 150 ms mean extra delay per subtask, uncoded waits for all.
    let slow = t((0..3).map(|_| WorkerFaults::with_send_delay(0.15)).collect());
    assert!(
        slow > fast + 0.15,
        "injection had no effect: fast={fast:.3}s slow={slow:.3}s"
    );
}
