//! Elastic-membership churn suite, over REAL TCP links: workers join a
//! running cluster (`Master::listen` + `run_worker_announcing`), die
//! mid-round, time out their heartbeats, and reconnect — and every
//! in-flight request must still complete with the right answer.
//!
//! Pool geometry: the plan is sized for `planned_workers = 3` with
//! `Fixed(3)` so tinyvgg's conv6 is type-1 under the paper profile
//! (L_int ≈ 124.7 ms < 130.3 ms local — deterministic planner math);
//! a 2-worker plan would distribute nothing and the churn paths under
//! test would silently no-op.

use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::Result;

use cocoi::conv::{ConvSpec, Tensor};
use cocoi::coordinator::messages::{FromWorker, ToWorker, PROTOCOL_VERSION};
use cocoi::coordinator::{
    run_worker_announcing, InferenceRequest, InferenceServer, JoinOptions, Master, MasterConfig,
    SchemeKind, ServerConfig, WorkerConfig, WorkerExit, WorkerFaults,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::{ConvProvider, FallbackProvider};
use cocoi::telemetry::EventKind;
use cocoi::transport::split::split_tcp;
use cocoi::transport::tcp::{connect_with_backoff, Backoff, TcpLink};
use cocoi::transport::Link;
use cocoi::util::Rng;

/// [`ConvProvider`] wrapper for churn tests: counts conv calls, signals
/// the test thread on each one (the only externally observable "this
/// worker was admitted and received a dispatch" event), and optionally
/// stalls so a subtask stays in flight while the test severs the link.
struct ProbeSpy {
    inner: FallbackProvider,
    calls: AtomicUsize,
    signal: Mutex<mpsc::Sender<()>>,
    stall: Duration,
}

impl ProbeSpy {
    fn new(stall: Duration) -> (Arc<ProbeSpy>, mpsc::Receiver<()>) {
        let (tx, rx) = mpsc::channel();
        (
            Arc::new(ProbeSpy {
                inner: FallbackProvider::new(),
                calls: AtomicUsize::new(0),
                signal: Mutex::new(tx),
                stall,
            }),
            rx,
        )
    }
}

impl ConvProvider for ProbeSpy {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        let _ = self.signal.lock().unwrap().send(());
        if !self.stall.is_zero() {
            thread::sleep(self.stall);
        }
        self.inner.conv(spec, input, weights)
    }

    fn name(&self) -> &'static str {
        "probe-spy"
    }
}

/// Elastic master on an ephemeral port, wrapped in a serving front-end
/// (the engine's event loop is what folds membership churn into the
/// pool, so it must be running before anyone joins).
fn elastic_server(scheme: SchemeKind, heartbeat: Duration) -> (InferenceServer, SocketAddr) {
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(3),
        heartbeat,
        ..Default::default()
    };
    let mut master =
        Master::new_elastic("tinyvgg", config, 3, Arc::new(FallbackProvider::new())).unwrap();
    let addr = master.listen("127.0.0.1:0").unwrap();
    (InferenceServer::start(master, ServerConfig::default()), addr)
}

/// Spawn an announcing worker thread; returns its join handle plus a
/// clone of the TCP stream so the test can sever the link mid-flight.
fn spawn_member(
    addr: SocketAddr,
    name: &str,
    provider: Arc<dyn ConvProvider>,
) -> (thread::JoinHandle<Result<WorkerExit>>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    let severable = stream.try_clone().unwrap();
    let name = name.to_string();
    let handle = thread::Builder::new()
        .name(format!("member-{name}"))
        .spawn(move || {
            let (tx, rx) = split_tcp(stream)?;
            run_worker_announcing(
                Box::new(tx),
                Box::new(rx),
                WorkerConfig {
                    id: 0, // reassigned from JoinAck
                    provider,
                    faults: WorkerFaults::none(),
                    rng_seed: 0xBEEF,
                    slots: 1,
                    trace: None,
                },
                &JoinOptions {
                    name,
                    model: String::new(),
                },
            )
        })
        .unwrap();
    (handle, severable)
}

fn input_for(seed: u64) -> Tensor {
    let model = zoo::model("tinyvgg").unwrap();
    let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
    Rng::new(seed).fill_uniform_f32(&mut t.data, -1.0, 1.0);
    t
}

fn local_ref(input: &Tensor) -> Tensor {
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    forward_local(&model, &weights, input).unwrap()
}

/// Worker ids of the master's membership events matching `pred`.
fn members_with(master: &Master, pred: fn(&EventKind) -> bool) -> Vec<usize> {
    master
        .registry()
        .events()
        .iter()
        .filter(|e| pred(&e.kind))
        .map(|e| e.worker)
        .collect()
}

const JOIN_WAIT: Duration = Duration::from_secs(30);

/// A worker killed mid-round — link severed while it holds a dispatched
/// subtask — must be evicted and its orphan re-dispatched: the request
/// completes on the survivor with the right answer. Uncoded and MDS at
/// n = k both have zero slack, so the re-dispatch is mandatory.
#[test]
fn killed_worker_mid_round_redispatches_and_completes() {
    for scheme in [SchemeKind::Uncoded, SchemeKind::Mds] {
        let (server, addr) = elastic_server(scheme, Duration::from_secs(10));

        let (spy_a, probe_a) = ProbeSpy::new(Duration::ZERO);
        let (survivor, _keep) = spawn_member(addr, "survivor", spy_a.clone());
        probe_a.recv_timeout(JOIN_WAIT).expect("survivor never probed");

        // The victim stalls 3 s in every conv, so its join probe pins
        // its only executor slot while the request round below assigns
        // it a subtask it will never answer.
        let (spy_v, probe_v) = ProbeSpy::new(Duration::from_secs(3));
        let (victim, sever) = spawn_member(addr, "victim", spy_v.clone());
        probe_v.recv_timeout(JOIN_WAIT).expect("victim never probed");

        // Both admitted (the probe only runs post-admission). The
        // survivor's SECOND conv call is its shard of the request's
        // distributed round — at that instant the victim's shard is
        // dispatched too (frames go out in one synchronous loop), so
        // severing now is guaranteed to orphan a victim-held subtask.
        let input = input_for(31);
        let want = local_ref(&input);
        let handle = server.submit(InferenceRequest::new(input)).unwrap();
        probe_a
            .recv_timeout(JOIN_WAIT)
            .expect("request round never reached the survivor");
        sever.shutdown(Shutdown::Both).unwrap();

        let (out, metrics) = handle.wait().unwrap();
        let err = out.max_abs_diff(&want);
        assert!(err < 2e-2, "{scheme:?}: churn output off local by {err}");
        assert!(metrics.layers.iter().any(|l| l.distributed));
        assert!(
            metrics.redispatches() >= 1,
            "{scheme:?}: the orphaned subtask must be re-dispatched"
        );

        let master = server.shutdown().unwrap();
        assert_eq!(
            members_with(&master, |k| matches!(k, EventKind::Joined)).len(),
            2
        );
        assert!(!members_with(&master, |k| matches!(k, EventKind::Evicted)).is_empty());
        assert_eq!(
            master.registry().worker_ids().len(),
            1,
            "only the survivor remains"
        );
        let json = master.telemetry_json().to_string();
        assert!(json.contains("members"), "membership missing from telemetry");
        master.shutdown();
        assert_eq!(survivor.join().unwrap().unwrap(), WorkerExit::Shutdown);
        let _ = victim.join().unwrap(); // LinkClosed: it was severed
    }
}

/// The rateless inverse of the kill case above: under `--scheme lt` the
/// same mid-round kill needs NO re-dispatch. The LT round spreads a
/// `2k + 16` symbol budget over both workers, so after the victim's
/// eviction the survivor's outstanding symbols still exceed the
/// decoder's rank-`k` need — `needs_redispatch` stays false and the
/// round completes on whatever useful symbols arrive.
#[test]
fn killed_worker_mid_round_lt_round_completes_without_redispatch() {
    let (server, addr) = elastic_server(SchemeKind::LtCoarse, Duration::from_secs(10));

    let (spy_a, probe_a) = ProbeSpy::new(Duration::ZERO);
    let (survivor, _keep) = spawn_member(addr, "survivor", spy_a.clone());
    probe_a.recv_timeout(JOIN_WAIT).expect("survivor never probed");

    let (spy_v, probe_v) = ProbeSpy::new(Duration::from_secs(3));
    let (victim, sever) = spawn_member(addr, "victim", spy_v.clone());
    probe_v.recv_timeout(JOIN_WAIT).expect("victim never probed");

    let input = input_for(37);
    let want = local_ref(&input);
    let handle = server.submit(InferenceRequest::new(input)).unwrap();
    probe_a
        .recv_timeout(JOIN_WAIT)
        .expect("request round never reached the survivor");
    sever.shutdown(Shutdown::Both).unwrap();

    let (out, metrics) = handle.wait().unwrap();
    let err = out.max_abs_diff(&want);
    assert!(err < 2e-2, "lt churn output off local by {err}");
    assert!(metrics.layers.iter().any(|l| l.distributed));
    assert_eq!(
        metrics.redispatches(),
        0,
        "a rateless round must absorb the eviction without re-dispatch"
    );

    let master = server.shutdown().unwrap();
    assert!(!members_with(&master, |k| matches!(k, EventKind::Evicted)).is_empty());
    assert_eq!(
        master.registry().worker_ids().len(),
        1,
        "only the survivor remains"
    );
    master.shutdown();
    assert_eq!(survivor.join().unwrap().unwrap(), WorkerExit::Shutdown);
    let _ = victim.join().unwrap(); // LinkClosed: it was severed
}

/// A worker that joins a RUNNING cluster is admitted, probed, and starts
/// receiving real dispatches — while requests served before, during,
/// and after the join all stay correct.
#[test]
fn late_joiner_is_admitted_and_receives_dispatches() {
    let (server, addr) = elastic_server(SchemeKind::Mds, Duration::from_secs(10));

    let (spy_a, probe_a) = ProbeSpy::new(Duration::ZERO);
    let (founder, _keep_a) = spawn_member(addr, "founder", spy_a.clone());
    probe_a.recv_timeout(JOIN_WAIT).expect("founder never probed");

    // Solo service first: a pool of one carries a request alone.
    let i0 = input_for(41);
    let w0 = local_ref(&i0);
    let (out, _) = server
        .submit(InferenceRequest::new(i0))
        .unwrap()
        .wait()
        .unwrap();
    assert!(out.max_abs_diff(&w0) < 2e-2);

    // Join a second worker into the running cluster, then keep serving.
    let (spy_b, probe_b) = ProbeSpy::new(Duration::ZERO);
    let (joiner, _keep_b) = spawn_member(addr, "late-joiner", spy_b.clone());
    probe_b.recv_timeout(JOIN_WAIT).expect("late joiner never probed");
    let probed = spy_b.calls.load(Ordering::SeqCst);

    for seed in [42u64, 43, 44] {
        let input = input_for(seed);
        let want = local_ref(&input);
        let (out, metrics) = server
            .submit(InferenceRequest::new(input))
            .unwrap()
            .wait()
            .unwrap();
        assert!(out.max_abs_diff(&want) < 2e-2);
        assert!(metrics.layers.iter().any(|l| l.distributed));
    }
    assert!(
        spy_b.calls.load(Ordering::SeqCst) > probed,
        "late joiner never received a post-join dispatch"
    );

    let master = server.shutdown().unwrap();
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Joined)),
        vec![0, 1]
    );
    assert_eq!(master.registry().worker_ids(), vec![0, 1]);
    assert!(
        master.registry().samples_of(1) > 0,
        "join probe must seed the joiner's capacity estimate"
    );
    master.shutdown();
    assert_eq!(founder.join().unwrap().unwrap(), WorkerExit::Shutdown);
    assert_eq!(joiner.join().unwrap().unwrap(), WorkerExit::Shutdown);
}

/// Elastic follow-up (b), pinned: a request in flight on a ONE-worker
/// pool must pick up a mid-request joiner instead of staying serial.
/// The `Joined` arm forces a replan and admits the joiner into the
/// dispatch set immediately, so the reliability watchdog's next pass
/// hedges the founder's wedged shards onto the fresh worker — the
/// joiner computes shards of the SAME request (its conv calls move past
/// the join probe before the handle resolves), and the uncoded decode
/// stays bitwise-local because every copy computes identical bytes.
#[test]
fn mid_request_joiner_rescues_inflight_round() {
    let (server, addr) = elastic_server(SchemeKind::Uncoded, Duration::from_secs(10));

    // The founder stalls 1.2 s in every conv — its join probe pins the
    // slot, so every shard of the request's distributed round sits
    // outstanding far past the watchdog's hedge floor.
    let (spy_f, probe_f) = ProbeSpy::new(Duration::from_millis(1200));
    let (founder, _keep_f) = spawn_member(addr, "founder", spy_f.clone());
    probe_f.recv_timeout(JOIN_WAIT).expect("founder never probed");

    let input = input_for(61);
    let want = local_ref(&input);
    let handle = server.submit(InferenceRequest::new(input)).unwrap();

    // Join a fast worker while the round is wedged on the founder.
    let (spy_j, probe_j) = ProbeSpy::new(Duration::ZERO);
    let (joiner, _keep_j) = spawn_member(addr, "rescuer", spy_j.clone());
    probe_j.recv_timeout(JOIN_WAIT).expect("joiner never probed");
    let probed = spy_j.calls.load(Ordering::SeqCst);

    let (out, metrics) = handle.wait().unwrap();
    assert_eq!(out.data, want.data, "rescued round output not bitwise-local");
    assert!(
        metrics.hedges() >= 1,
        "watchdog never hedged the wedged shards onto the joiner"
    );
    assert!(
        spy_j.calls.load(Ordering::SeqCst) > probed,
        "mid-request joiner never received a shard of the in-flight request"
    );

    let master = server.shutdown().unwrap();
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Joined)),
        vec![0, 1]
    );
    master.shutdown();
    assert_eq!(founder.join().unwrap().unwrap(), WorkerExit::Shutdown);
    assert_eq!(joiner.join().unwrap().unwrap(), WorkerExit::Shutdown);
}

/// A peer that completes the join handshake and then goes silent — no
/// heartbeats, no replies — must be evicted once the master's heartbeat
/// read-deadline lapses.
#[test]
fn silent_peer_is_evicted_on_heartbeat_timeout() {
    let heartbeat = Duration::from_millis(300);
    let (server, addr) = elastic_server(SchemeKind::Uncoded, heartbeat);

    // Manual handshake: Join -> JoinAck -> Ready -> silence.
    let mut link = TcpLink::connect(&addr.to_string()).unwrap();
    link.send(
        &FromWorker::Join {
            name: "mute".into(),
            protocol: PROTOCOL_VERSION,
            model: String::new(),
        }
        .encode(),
    )
    .unwrap();
    let frame = link.recv().unwrap().expect("master closed during handshake");
    match ToWorker::decode(&frame).unwrap() {
        ToWorker::JoinAck {
            worker_id,
            heartbeat_ms,
            ..
        } => {
            assert_eq!(worker_id, 0);
            // The master asks for beats at a third of the deadline.
            assert_eq!(u128::from(heartbeat_ms), heartbeat.as_millis() / 3);
        }
        other => panic!("expected JoinAck, got {other:?}"),
    }
    link.send(&FromWorker::Ready.encode()).unwrap();

    // Never beat. The per-link read-timeout (== the heartbeat deadline)
    // lapses, the reader emits LinkDown, and the engine evicts.
    thread::sleep(heartbeat * 8);

    let master = server.shutdown().unwrap();
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Joined)),
        vec![0]
    );
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Evicted)),
        vec![0]
    );
    assert!(master.registry().worker_ids().is_empty());
    master.shutdown();
}

/// A heartbeat whose `seq` regresses is a replayed/stale beacon from a
/// zombie half-open link: it must NOT refresh the liveness deadline,
/// and it takes a strike on `cocoi_heartbeat_regressions_total`.
/// Monotonically advancing beats take none.
#[test]
fn stale_heartbeat_replay_takes_a_strike() {
    // Heartbeat deadline far beyond the test's lifetime so eviction
    // never races the assertions — only the seq bookkeeping is on trial.
    let (server, addr) = elastic_server(SchemeKind::Uncoded, Duration::from_secs(30));

    // Manual handshake, same idiom as the silent-peer test.
    let mut link = TcpLink::connect(&addr.to_string()).unwrap();
    link.send(
        &FromWorker::Join {
            name: "replayer".into(),
            protocol: PROTOCOL_VERSION,
            model: String::new(),
        }
        .encode(),
    )
    .unwrap();
    let frame = link.recv().unwrap().expect("master closed during handshake");
    match ToWorker::decode(&frame).unwrap() {
        ToWorker::JoinAck { worker_id, .. } => assert_eq!(worker_id, 0),
        other => panic!("expected JoinAck, got {other:?}"),
    }
    link.send(&FromWorker::Ready.encode()).unwrap();

    // Healthy beats advance strictly (3 then 5): no strikes. Then a
    // replayed 4 and a duplicated 5 both sit at-or-below the last-seen
    // seq and each takes one strike.
    for seq in [3u64, 5] {
        link.send(&FromWorker::Heartbeat { seq }.encode()).unwrap();
    }
    for seq in [4u64, 5] {
        link.send(&FromWorker::Heartbeat { seq }.encode()).unwrap();
    }

    // Beats fold in on the engine thread; poll the scrape briefly.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut text = String::new();
    while std::time::Instant::now() < deadline {
        text = server.scrape().to_prometheus();
        if text.contains("cocoi_heartbeat_regressions_total 2") {
            break;
        }
        thread::sleep(Duration::from_millis(20));
    }
    assert!(
        text.contains("cocoi_heartbeat_regressions_total 2"),
        "expected exactly two seq-regression strikes in scrape, got:\n{text}"
    );

    // The strikes were observational only: the worker is still a member.
    let master = server.shutdown().unwrap();
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Joined)),
        vec![0]
    );
    assert!(members_with(&master, |k| matches!(k, EventKind::Evicted)).is_empty());
    assert_eq!(master.registry().worker_ids(), vec![0]);
    master.shutdown();
}

/// A worker whose link drops dials back with capped exponential backoff,
/// re-joins under a FRESH id (the old membership was already evicted),
/// and serves requests again.
#[test]
fn reconnect_after_link_drop_rejoins_and_serves() {
    let (server, addr) = elastic_server(SchemeKind::Uncoded, Duration::from_secs(10));

    let (spy, probes) = ProbeSpy::new(Duration::ZERO);
    let current: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
    let stash = current.clone();
    let provider: Arc<dyn ConvProvider> = spy.clone();
    let addr_s = addr.to_string();
    // The same dial loop `cocoi worker --connect` runs.
    let worker = thread::Builder::new()
        .name("reconnector".into())
        .spawn(move || -> Result<()> {
            let backoff = Backoff {
                initial: Duration::from_millis(20),
                max: Duration::from_millis(200),
                factor: 2.0,
                retries: 50,
            };
            loop {
                let link = connect_with_backoff(&addr_s, &backoff)?;
                let stream = link.into_stream();
                *stash.lock().unwrap() = Some(stream.try_clone()?);
                let (tx, rx) = split_tcp(stream)?;
                let exit = run_worker_announcing(
                    Box::new(tx),
                    Box::new(rx),
                    WorkerConfig {
                        id: 0,
                        provider: provider.clone(),
                        faults: WorkerFaults::none(),
                        rng_seed: 0xFEED,
                        slots: 1,
                        trace: None,
                    },
                    &JoinOptions {
                        name: "phoenix".into(),
                        model: String::new(),
                    },
                )?;
                match exit {
                    WorkerExit::Shutdown => return Ok(()),
                    WorkerExit::LinkClosed => continue, // dial again
                }
            }
        })
        .unwrap();

    // First membership admitted (its probe ran) — now cut the link.
    probes.recv_timeout(JOIN_WAIT).expect("first join never probed");
    current
        .lock()
        .unwrap()
        .as_ref()
        .unwrap()
        .shutdown(Shutdown::Both)
        .unwrap();

    // Second membership: the reconnect loop re-joins under a new id and
    // gets probed again.
    probes
        .recv_timeout(JOIN_WAIT)
        .expect("never re-joined after the link drop");

    let input = input_for(53);
    let want = local_ref(&input);
    let (out, _) = server
        .submit(InferenceRequest::new(input))
        .unwrap()
        .wait()
        .unwrap();
    assert!(out.max_abs_diff(&want) < 2e-2);

    let master = server.shutdown().unwrap();
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Joined)),
        vec![0, 1]
    );
    assert_eq!(
        members_with(&master, |k| matches!(k, EventKind::Evicted)),
        vec![0]
    );
    assert!(!master.registry().contains(0));
    assert!(master.registry().contains(1));
    master.shutdown();
    worker.join().unwrap().unwrap();
}
