//! Coordinator-pipeline suite: the pipelined engine must produce the
//! same inference outputs as the round-barrier path (and as pure local
//! execution), under healthy pools, failures, and stragglers, for
//! single requests and multiplexed batches. Runs without `artifacts/`.

use std::sync::Arc;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, LocalCluster, MasterConfig, SchemeKind, WorkerFaults,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn inputs_for(model_name: &str, count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(model_name: &str, inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn run_batch(
    model_name: &str,
    scheme: SchemeKind,
    mode: ExecMode,
    n: usize,
    k: usize,
    faults: Vec<WorkerFaults>,
    inputs: &[Tensor],
) -> Vec<(Tensor, cocoi::coordinator::InferenceMetrics)> {
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        mode,
        ..Default::default()
    };
    let mut cluster =
        LocalCluster::spawn(model_name, n, config, Arc::new(FallbackProvider::new()), faults)
            .unwrap();
    let out = cluster.master.infer_batch(inputs).unwrap();
    cluster.shutdown().unwrap();
    out
}

/// Single request: the pipelined engine must agree with the round-barrier
/// path (same seed, same weights) and with local inference.
#[test]
fn pipelined_single_request_matches_barrier() {
    let inputs = inputs_for("tinyvgg", 1, 101);
    let want = local_refs("tinyvgg", &inputs);
    let healthy = |n: usize| (0..n).map(|_| WorkerFaults::none()).collect::<Vec<_>>();
    let barrier = run_batch(
        "tinyvgg",
        SchemeKind::Mds,
        ExecMode::RoundBarrier,
        4,
        3,
        healthy(4),
        &inputs,
    );
    let pipe = run_batch(
        "tinyvgg",
        SchemeKind::Mds,
        ExecMode::Pipelined,
        4,
        3,
        healthy(4),
        &inputs,
    );
    assert_eq!(barrier.len(), 1);
    assert_eq!(pipe.len(), 1);
    // Both correct vs local...
    assert!(barrier[0].0.max_abs_diff(&want[0]) < 2e-2);
    assert!(pipe[0].0.max_abs_diff(&want[0]) < 2e-2);
    // ...and equal to each other up to MDS decode round-off (which
    // k-subset wins the race is timing-dependent; all subsets decode the
    // same values modulo float error).
    let gap = pipe[0].0.max_abs_diff(&barrier[0].0);
    assert!(gap < 2e-2, "modes disagree by {gap}");
    assert!(pipe[0].1.layers.iter().any(|l| l.distributed));
}

/// With the uncoded scheme the decode is an exact passthrough of all n
/// pieces regardless of arrival order, so the two engines must produce
/// *bitwise identical* outputs on the same seed.
#[test]
fn pipelined_uncoded_bitwise_identical_to_barrier() {
    let inputs = inputs_for("tinyvgg", 2, 808);
    let healthy = (0..3).map(|_| WorkerFaults::none()).collect::<Vec<_>>();
    let barrier = run_batch(
        "tinyvgg",
        SchemeKind::Uncoded,
        ExecMode::RoundBarrier,
        3,
        3,
        healthy.clone(),
        &inputs,
    );
    let pipe = run_batch(
        "tinyvgg",
        SchemeKind::Uncoded,
        ExecMode::Pipelined,
        3,
        3,
        healthy,
        &inputs,
    );
    for (i, ((b, _), (p, _))) in barrier.iter().zip(&pipe).enumerate() {
        assert_eq!(b.shape(), p.shape());
        assert_eq!(
            b.data, p.data,
            "request {i}: engines diverged on deterministic decode"
        );
    }
}

/// A multiplexed batch: every response must match its own local
/// reference (no cross-request mixups) for MDS and replication.
#[test]
fn pipelined_batch_matches_local() {
    for (scheme, n, k) in [(SchemeKind::Mds, 4, 3), (SchemeKind::Replication, 4, 2)] {
        let inputs = inputs_for("tinyvgg", 4, 202);
        let want = local_refs("tinyvgg", &inputs);
        let faults = (0..n).map(|_| WorkerFaults::none()).collect();
        let got = run_batch("tinyvgg", scheme, ExecMode::Pipelined, n, k, faults, &inputs);
        assert_eq!(got.len(), inputs.len());
        for (i, ((out, metrics), want)) in got.iter().zip(&want).enumerate() {
            let err = out.max_abs_diff(want);
            assert!(err < 2e-2, "{scheme:?} request {i}: err {err}");
            assert!(metrics.layers.iter().any(|l| l.distributed));
            assert!(metrics.total_seconds > 0.0);
        }
    }
}

/// The DAG model (skip connections) through the pipelined engine.
#[test]
fn pipelined_resnet_batch_matches_local() {
    let inputs = inputs_for("tinyresnet", 3, 303);
    let want = local_refs("tinyresnet", &inputs);
    let faults = (0..3).map(|_| WorkerFaults::none()).collect();
    let got = run_batch(
        "tinyresnet",
        SchemeKind::Mds,
        ExecMode::Pipelined,
        3,
        2,
        faults,
        &inputs,
    );
    for ((out, _), want) in got.iter().zip(&want) {
        assert!(out.max_abs_diff(want) < 2e-2);
    }
}

/// MDS redundancy absorbs a permanently failing worker in pipelined mode
/// without re-dispatch; outputs stay correct for the whole batch.
#[test]
fn pipelined_batch_survives_failures() {
    let n = 4;
    let inputs = inputs_for("tinyvgg", 3, 404);
    let want = local_refs("tinyvgg", &inputs);
    let faults: Vec<WorkerFaults> = (0..n)
        .map(|i| {
            if i == 2 {
                WorkerFaults::none().fails_in(0..1024)
            } else {
                WorkerFaults::none()
            }
        })
        .collect();
    let got = run_batch(
        "tinyvgg",
        SchemeKind::Mds,
        ExecMode::Pipelined,
        n,
        3,
        faults,
        &inputs,
    );
    let mut failures = 0;
    for (i, ((out, metrics), want)) in got.iter().zip(&want).enumerate() {
        let err = out.max_abs_diff(want);
        assert!(err < 2e-2, "request {i}: err {err}");
        failures += metrics.failures();
        assert_eq!(metrics.redispatches(), 0, "k=3, n=4 absorbs one failure");
    }
    assert!(failures > 0, "the failing worker must have been observed");
}

/// Uncoded needs every piece: a failing worker forces re-dispatch, and
/// the pipelined engine must still deliver correct batch results.
#[test]
fn pipelined_uncoded_redispatches_and_recovers() {
    let n = 3;
    let inputs = inputs_for("tinyvgg", 2, 505);
    let want = local_refs("tinyvgg", &inputs);
    let faults: Vec<WorkerFaults> = (0..n)
        .map(|i| {
            if i == 0 {
                WorkerFaults::none().fails_in(0..4)
            } else {
                WorkerFaults::none()
            }
        })
        .collect();
    let got = run_batch(
        "tinyvgg",
        SchemeKind::Uncoded,
        ExecMode::Pipelined,
        n,
        3,
        faults,
        &inputs,
    );
    let mut redispatches = 0;
    for ((out, metrics), want) in got.iter().zip(&want) {
        assert!(out.max_abs_diff(want) < 2e-2);
        redispatches += metrics.redispatches();
    }
    assert!(redispatches > 0, "uncoded must re-execute failed pieces");
}

/// A chronic straggler slows one worker; the engine cancels its stale
/// subtasks after each decode and the batch still completes correctly.
#[test]
fn pipelined_straggler_cancelled_not_corrupting() {
    let n = 4;
    let inputs = inputs_for("tinyvgg", 3, 606);
    let want = local_refs("tinyvgg", &inputs);
    let mut faults: Vec<WorkerFaults> = (0..n).map(|_| WorkerFaults::none()).collect();
    faults[0] = WorkerFaults::with_send_delay(0.05);
    let got = run_batch(
        "tinyvgg",
        SchemeKind::Mds,
        ExecMode::Pipelined,
        n,
        3,
        faults,
        &inputs,
    );
    for ((out, _), want) in got.iter().zip(&want) {
        assert!(out.max_abs_diff(want) < 2e-2);
    }
    // With a 50 ms delay on worker 0's sends and 6 distributed layers x 3
    // requests racing, at least one round should decode before the
    // straggler reports, i.e. some subtask gets cancelled.
    let cancelled: usize = got.iter().map(|(_, m)| m.cancelled()).sum();
    assert!(cancelled > 0, "expected straggler cancellations");
}

/// Steady-state scratch reuse + prepacked weights on the workers must
/// not perturb outputs: repeating the same request through one
/// long-lived cluster gives *bitwise identical* uncoded outputs every
/// time (the later runs hit fully warmed scratch arenas), and MDS stays
/// within decode tolerance of the local reference on every repeat
/// (which k-subset wins each race is timing-dependent).
#[test]
fn scratch_reuse_keeps_repeat_outputs_stable() {
    let inputs = inputs_for("tinyvgg", 1, 909);
    let want = local_refs("tinyvgg", &inputs);

    // Uncoded, n == k: decode is an exact passthrough, so any output
    // drift would have to come from worker-side buffer reuse.
    let config = MasterConfig {
        scheme: SchemeKind::Uncoded,
        policy: SplitPolicy::Fixed(3),
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        3,
        config,
        Arc::new(FallbackProvider::new()),
        (0..3).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let (first, _) = cluster.master.infer(&inputs[0]).unwrap();
    for round in 0..2 {
        let (again, _) = cluster.master.infer(&inputs[0]).unwrap();
        assert_eq!(
            first.data, again.data,
            "scratch reuse changed worker outputs (repeat {round})"
        );
    }
    cluster.shutdown().unwrap();
    assert!(first.max_abs_diff(&want[0]) < 2e-2);

    // MDS through its own long-lived cluster: every repeat decodes to
    // the same values within tolerance.
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(3),
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        4,
        config,
        Arc::new(FallbackProvider::new()),
        (0..4).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    for round in 0..3 {
        let (got, _) = cluster.master.infer(&inputs[0]).unwrap();
        let err = got.max_abs_diff(&want[0]);
        assert!(err < 2e-2, "MDS repeat {round}: err {err}");
    }
    cluster.shutdown().unwrap();
}

/// Degenerate batch: `infer_batch(&[])` returns `Ok(vec![])` in both
/// modes without dispatching anything to the workers — and the pool
/// still serves real work afterwards.
#[test]
fn empty_batch_returns_empty() {
    for mode in [ExecMode::RoundBarrier, ExecMode::Pipelined] {
        let config = MasterConfig {
            scheme: SchemeKind::Mds,
            policy: SplitPolicy::Fixed(3),
            mode,
            ..Default::default()
        };
        let mut cluster = LocalCluster::spawn(
            "tinyvgg",
            4,
            config,
            Arc::new(FallbackProvider::new()),
            (0..4).map(|_| WorkerFaults::none()).collect(),
        )
        .unwrap();
        let out = cluster.master.infer_batch(&[]).unwrap();
        assert!(out.is_empty(), "{mode:?}: empty batch must yield no results");
        let inputs = inputs_for("tinyvgg", 1, 1234);
        let got = cluster.master.infer_batch(&inputs).unwrap();
        assert_eq!(got.len(), 1, "{mode:?}: pool unusable after empty batch");
        cluster.shutdown().unwrap();
    }
}

/// Barrier-mode infer_batch == sequential infer (sanity of the baseline
/// the throughput experiment compares against).
#[test]
fn barrier_batch_equals_sequential_infers() {
    let inputs = inputs_for("tinyvgg", 2, 707);
    let want = local_refs("tinyvgg", &inputs);
    let faults = (0..4).map(|_| WorkerFaults::none()).collect();
    let got = run_batch(
        "tinyvgg",
        SchemeKind::Mds,
        ExecMode::RoundBarrier,
        4,
        3,
        faults,
        &inputs,
    );
    assert_eq!(got.len(), 2);
    for ((out, _), want) in got.iter().zip(&want) {
        assert!(out.max_abs_diff(want) < 2e-2);
    }
}
