//! Cross-request shard-coalescing property suite.
//!
//! The coalescing contract: merging several concurrent requests'
//! same-layer shards into one multi-payload round is a pure *scheduling*
//! optimization — it must never change what any request computes.
//! Pinned here as: fixed-seed randomized mixes of request counts and
//! priorities through the coalesced engine are bitwise-identical to the
//! uncoalesced engine AND to `Master::infer` run serially on the
//! deterministic uncoded decode, within decode tolerance of local under
//! MDS, and both still hold under mid-batch straggler cancellation and
//! staggered (different-layer) submission streams.

use std::sync::Arc;
use std::time::Duration;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, PoolOptions,
    SchemeKind, ServerConfig, WorkerFaults,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn inputs_for(count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn cluster(
    scheme: SchemeKind,
    n: usize,
    k: usize,
    mode: ExecMode,
    coalesce: usize,
    worker_slots: usize,
    faults: Vec<WorkerFaults>,
) -> LocalCluster {
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        mode,
        coalesce,
        ..Default::default()
    };
    LocalCluster::spawn_with(
        "tinyvgg",
        n,
        config,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots },
    )
    .unwrap()
}

fn healthy(n: usize) -> Vec<WorkerFaults> {
    (0..n).map(|_| WorkerFaults::none()).collect()
}

/// Batch the inputs through a pipelined engine with the given knobs.
fn run_batch(inputs: &[Tensor], coalesce: usize, slots: usize) -> Vec<Tensor> {
    let mut c = cluster(
        SchemeKind::Uncoded,
        3,
        3,
        ExecMode::Pipelined,
        coalesce,
        slots,
        healthy(3),
    );
    let outs = c.master.infer_batch(inputs).unwrap();
    c.shutdown().unwrap();
    outs.into_iter().map(|(t, _)| t).collect()
}

/// THE coalescing correctness pin: fixed-seed randomized request counts
/// through coalesced / uncoalesced / serial engines agree BITWISE on the
/// uncoded path (identity decode + bitwise-stable batched GEMM).
#[test]
fn randomized_mixes_bitwise_equal_across_engines() {
    let mut rng = Rng::new(0xC0A1);
    for trial in 0..4 {
        let count = 1 + rng.below(5); // 1..=5 requests
        let inputs = inputs_for(count, 0xBEE5 ^ trial);

        // Serial reference: one request at a time through infer().
        let serial: Vec<Tensor> = {
            let mut c = cluster(
                SchemeKind::Uncoded,
                3,
                3,
                ExecMode::RoundBarrier,
                1,
                1,
                healthy(3),
            );
            let outs = inputs
                .iter()
                .map(|i| c.master.infer(i).unwrap().0)
                .collect();
            c.shutdown().unwrap();
            outs
        };
        let plain = run_batch(&inputs, 1, 1);
        let coalesced = run_batch(&inputs, 4, 1);
        let coalesced_slotted = run_batch(&inputs, 4, 2);
        for i in 0..count {
            assert_eq!(
                plain[i].data, serial[i].data,
                "trial {trial} req {i}: uncoalesced engine != serial"
            );
            assert_eq!(
                coalesced[i].data, serial[i].data,
                "trial {trial} req {i}: coalesced engine != serial"
            );
            assert_eq!(
                coalesced_slotted[i].data, serial[i].data,
                "trial {trial} req {i}: coalesced+slots engine != serial"
            );
        }
    }
}

/// Coalesced MDS serving with randomized priorities: every request's
/// answer stays within decode tolerance of local inference, whichever
/// batch its shards rode in.
#[test]
fn coalesced_mds_with_priorities_matches_local() {
    let inputs = inputs_for(6, 77);
    let want = local_refs(&inputs);
    let c = cluster(SchemeKind::Mds, 4, 3, ExecMode::Pipelined, 3, 2, healthy(4));
    let (master, workers) = c.into_parts();
    let server = InferenceServer::start(master, ServerConfig::default());
    let mut rng = Rng::new(5);
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| {
            server
                .submit(InferenceRequest::new(i.clone()).with_priority(rng.below(4) as u8))
                .unwrap()
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, metrics) = h.wait().unwrap();
        let err = out.max_abs_diff(want);
        assert!(err < 2e-2, "coalesced MDS off local by {err}");
        assert!(metrics.layers.iter().any(|l| l.distributed));
    }
    stop(server, workers);
}

fn stop(server: InferenceServer, workers: cocoi::coordinator::WorkerHandles) {
    let master = server.shutdown().unwrap();
    master.shutdown();
    workers.join().unwrap();
}

/// Mid-batch cancellation: MDS(k=2, n=4) with one slow-sending worker
/// cancels two straggler shards per round while the round's other
/// requests ride the same coalesced frames. Outputs stay within
/// tolerance and the metrics show the cancellations actually happened.
#[test]
fn coalesced_output_correct_under_mid_batch_cancellation() {
    let inputs = inputs_for(5, 31);
    let want = local_refs(&inputs);
    let mut faults = healthy(4);
    // One chronically slow link: its shard is routinely the straggler
    // that gets cancelled after the round decodes from the fast three.
    faults[3] = WorkerFaults::with_send_delay(0.03);
    let mut c = cluster(SchemeKind::Mds, 4, 2, ExecMode::Pipelined, 4, 1, faults);
    let results = c.master.infer_batch(&inputs).unwrap();
    let cancelled: usize = results.iter().map(|(_, m)| m.cancelled()).sum();
    for ((out, _), want) in results.iter().zip(&want) {
        let err = out.max_abs_diff(want);
        assert!(err < 2e-2, "cancellation run off local by {err}");
    }
    // With a 30 ms straggler on every round and k=2-of-4 decode, at
    // least one straggler shard must have been cancelled mid-batch.
    assert!(cancelled > 0, "expected mid-batch cancellations");
    c.shutdown().unwrap();
}

/// Layer-offset mixes: a staggered stream (later submissions arrive
/// while earlier requests are deep in the model) coalesces only
/// same-layer groups; everything still matches local. The pacing makes
/// grouping nondeterministic on purpose — correctness may not depend on
/// which requests happened to batch.
#[test]
fn staggered_stream_coalesces_safely() {
    let inputs = inputs_for(6, 99);
    let want = local_refs(&inputs);
    let c = cluster(SchemeKind::Uncoded, 3, 3, ExecMode::Pipelined, 4, 2, healthy(3));
    let (master, workers) = c.into_parts();
    let server = InferenceServer::start(master, ServerConfig::default());
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, input)| {
            if i > 0 && i % 2 == 0 {
                std::thread::sleep(Duration::from_millis(3));
            }
            server.submit(InferenceRequest::new(input.clone())).unwrap()
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, _) = h.wait().unwrap();
        // Uncoded: bitwise-local regardless of which batches formed.
        assert_eq!(out.data, want.data, "staggered uncoded output not bitwise-local");
    }
    stop(server, workers);
}

/// The per-request latency metrics of a coalesced batch stay coherent:
/// every request reports each distributed layer exactly once, with the
/// coalesced round's shared phases accounted per request.
#[test]
fn coalesced_metrics_report_every_layer_once() {
    let inputs = inputs_for(3, 55);
    let mut c = cluster(
        SchemeKind::Uncoded,
        3,
        3,
        ExecMode::Pipelined,
        4,
        1,
        healthy(3),
    );
    let results = c.master.infer_batch(&inputs).unwrap();
    let model = zoo::model("tinyvgg").unwrap();
    let n_convs = model
        .nodes
        .iter()
        .filter(|n| matches!(n.op, cocoi::model::Op::Conv { .. }))
        .count();
    for (_, metrics) in &results {
        assert_eq!(
            metrics.layers.len(),
            n_convs,
            "each conv layer reports exactly once per request"
        );
        for lm in metrics.layers.iter().filter(|l| l.distributed) {
            assert!(lm.t_workers >= 0.0 && lm.t_workers.is_finite());
            assert!(!lm.per_worker.is_empty(), "per-worker breakdown missing");
        }
    }
    c.shutdown().unwrap();
}
