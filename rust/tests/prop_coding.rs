//! Property tests for the coding layer (satellite of PR 1): for random
//! `(n, k)` and random shard data, encode → drop any `n − k` shards →
//! decode must reconstruct the input — exactly for replication/uncoded,
//! within 1e-3 for MDS, and with high probability for rateless LT. Plus
//! a conditioning regression pinning `Matrix::inverse` error growth on
//! the evenly-spaced Vandermonde nodes MDS actually uses.

use cocoi::coding::matrix::Matrix;
use cocoi::coding::{Decoder, LtCode, MdsCode, RedundancyScheme, Replication, Uncoded};
use cocoi::util::prop;
use cocoi::util::Rng;

fn random_sources(k: usize, len: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..k)
        .map(|_| (0..len).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
        .collect()
}

/// MDS: any `k` of the `n` encoded shards reconstruct the sources within
/// 1e-3 (drop a random `n − k`-subset each case).
#[test]
fn mds_encode_drop_decode_reconstructs() {
    prop::check("mds drop n-k", 64, |rng| {
        let n = 2 + rng.below(9); // 2..=10
        let k = 1 + rng.below(n); // 1..=n
        let len = 1 + rng.below(96);
        let code = MdsCode::new(n, k);
        let sources = random_sources(k, len, rng);
        let tasks = code.encode(&sources);
        assert_eq!(tasks.len(), n);

        // Keep a random k-subset == drop a random (n-k)-subset.
        let keep = rng.sample_distinct(n, k);
        let mut dec = code.decoder();
        let mut ready = false;
        for &t in &keep {
            ready = dec.add(tasks[t].id, tasks[t].payload.clone());
        }
        assert!(ready, "k shards must decode (n={n} k={k})");
        let decoded = dec.decode().unwrap();
        assert_eq!(decoded.len(), k);
        for (d, s) in decoded.iter().zip(&sources) {
            for (a, b) in d.iter().zip(s.iter()) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "mds(n={n},k={k}) decode {a} != {b}"
                );
            }
        }
    });
}

/// Replication: drop one replica of every source (the maximum loss the
/// scheme tolerates); reconstruction is bit-exact.
#[test]
fn replication_drop_one_replica_per_source_exact() {
    prop::check("replication drop replicas", 64, |rng| {
        let n = 2 + rng.below(9); // 2..=10
        let code = Replication::new(n);
        let k = code.source_count();
        let len = 1 + rng.below(64);
        let sources = random_sources(k, len, rng);
        let tasks = code.encode(&sources);

        // For each source pick exactly one surviving replica at random.
        let mut dec = code.decoder();
        let mut ready = false;
        for src in 0..k {
            let replicas: Vec<usize> = (0..tasks.len()).filter(|t| t % k == src).collect();
            let survivor = replicas[rng.below(replicas.len())];
            ready = dec.add(tasks[survivor].id, tasks[survivor].payload.clone());
        }
        assert!(ready);
        let decoded = dec.decode().unwrap();
        assert_eq!(decoded, sources, "replication must be exact");
    });
}

/// Uncoded: k = n, nothing can be dropped; the identity "code" is exact.
#[test]
fn uncoded_roundtrip_exact() {
    prop::check("uncoded roundtrip", 48, |rng| {
        let n = 1 + rng.below(10);
        let code = Uncoded::new(n);
        let sources = random_sources(n, 1 + rng.below(64), rng);
        let tasks = code.encode(&sources);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut dec = code.decoder();
        let mut ready = false;
        for &t in &order {
            ready = dec.add(tasks[t].id, tasks[t].payload.clone());
        }
        assert!(ready, "all n shards present");
        assert_eq!(dec.decode().unwrap(), sources);
    });
}

/// LT is rateless: with its default budget (2k + 16 symbols) a random
/// arrival order reaches rank k with high probability; when it does, the
/// GE decode reconstructs within 1e-3. A small deficient-rank rate is
/// inherent to LT, so failures are counted, not forbidden.
#[test]
fn lt_decodes_with_high_probability() {
    let cases = 48;
    let mut deficient = 0usize;
    prop::check("lt overhead decode", cases, |rng| {
        let n = 2 + rng.below(7); // workers, reporting only
        let k = 1 + rng.below(12);
        let len = 1 + rng.below(48);
        let code = LtCode::new(n, k, rng.next_u64());
        let sources = random_sources(k, len, rng);
        let tasks = code.encode(&sources);
        assert!(tasks.len() >= 2 * k, "rateless overhead budget");

        let mut order: Vec<usize> = (0..tasks.len()).collect();
        rng.shuffle(&mut order);
        let mut dec = code.decoder();
        let mut ready = false;
        for &t in &order {
            if dec.add(tasks[t].id, tasks[t].payload.clone()) {
                ready = true;
                break;
            }
        }
        if !ready {
            deficient += 1;
            return;
        }
        let decoded = dec.decode().unwrap();
        for (d, s) in decoded.iter().zip(&sources) {
            for (a, b) in d.iter().zip(s.iter()) {
                assert!((a - b).abs() < 1e-3, "lt(k={k}) decode {a} != {b}");
            }
        }
    });
    assert!(
        deficient * 10 <= cases,
        "LT rank-deficiency rate too high: {deficient}/{cases}"
    );
}

/// Conditioning regression: the inversion residual ‖G_S·G_S⁻¹ − I‖_max of
/// full-size Vandermonde systems on MdsCode's evenly-spaced nodes grows
/// with n but must stay under the pinned ceilings (float Vandermonde with
/// *integer* nodes would blow through these around k ≈ 8 — the spread
/// node layout is the mitigation this test protects).
#[test]
fn vandermonde_inverse_error_growth_pinned() {
    let ceilings = [(4usize, 1e-11f64), (8, 1e-9), (12, 1e-7), (16, 1e-5), (20, 1e-4)];
    let mut residuals = Vec::new();
    for &(n, ceiling) in &ceilings {
        let g = Matrix::vandermonde(&MdsCode::nodes(n), n);
        let inv = g.inverse().expect("full Vandermonde invertible");
        let prod = g.matmul(&inv);
        let mut res = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let expect = if i == j { 1.0 } else { 0.0 };
                res = res.max((prod[(i, j)] - expect).abs());
            }
        }
        assert!(
            res < ceiling,
            "n={n}: inverse residual {res:.3e} exceeds pinned ceiling {ceiling:.0e}"
        );
        residuals.push(res);
    }
    // Growth regression: the largest system must be measurably worse
    // conditioned than the smallest (if this stops holding, the node
    // layout changed — re-pin the ceilings).
    assert!(
        residuals[residuals.len() - 1] > residuals[0],
        "residuals no longer grow with n: {residuals:?}"
    );
}

/// Random k-subsets of MDS rows stay invertible and decode-accurate at
/// the paper's largest scale (n = 20).
#[test]
fn mds_paper_scale_subsets_stay_conditioned() {
    let n = 20;
    let mut rng = Rng::new(0x5EED);
    for k in [4usize, 8, 12, 16] {
        let g = Matrix::vandermonde(&MdsCode::nodes(n), k);
        for _ in 0..20 {
            let idx = rng.sample_distinct(n, k);
            let gs = g.select_rows(&idx);
            let inv = gs.inverse().expect("k-subset invertible");
            let prod = gs.matmul(&inv);
            for i in 0..k {
                assert!(
                    (prod[(i, i)] - 1.0).abs() < 1e-4,
                    "n={n} k={k}: diagonal {:.3e}",
                    prod[(i, i)]
                );
            }
        }
    }
}
