//! Reliability suite: the request-never-fails layer under faults the
//! clean-failure path cannot catch. A *stalled* worker (accepts the
//! subtask, never replies, link stays healthy) must be caught by the
//! fitted-quantile watchdog and hedged to a healthy worker; a pool
//! where EVERY copy stalls must be completed by the master-local
//! decode fallback; a fault-free run must never speculate; and a
//! coalesced stream must survive persistent failures under both a
//! generous and an exhausted retry budget (budget exhaustion escalates
//! to the fallback instead of erroring the request).
//!
//! Completion contract pinned here: with `local_fallback` on, every
//! admitted request resolves with output matching local compute —
//! bitwise on the uncoded path, within decode tolerance under MDS —
//! and the per-request metrics report how it got there (hedges /
//! redispatches / fallbacks).

use std::sync::Arc;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, PoolOptions,
    SchemeKind, ServerConfig, WorkerFaults, WorkerHandles,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn inputs_for(model_name: &str, count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(model_name: &str, inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model(model_name).unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn base_config(scheme: SchemeKind, k: usize) -> MasterConfig {
    MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        mode: ExecMode::Pipelined,
        ..Default::default()
    }
}

fn spawn(
    config: MasterConfig,
    n: usize,
    faults: Vec<WorkerFaults>,
) -> (InferenceServer, WorkerHandles) {
    let cluster = LocalCluster::spawn_with(
        "tinyvgg",
        n,
        config,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots: 1 },
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    (InferenceServer::start(master, ServerConfig::default()), workers)
}

fn stop(server: InferenceServer, workers: WorkerHandles) {
    let master = server.shutdown().unwrap();
    master.shutdown();
    workers.join().unwrap();
}

/// A black-hole stall on one worker — no Output, no Failed, link alive —
/// is exactly the fault only the watchdog can catch: the hedge fires
/// past the fitted/floored completion quantile, the copy races on a
/// healthy worker, and the uncoded output stays BITWISE-equal to local
/// (an encoded frame computes the same bytes on any worker).
#[test]
fn stalled_worker_is_hedged_bitwise() {
    let inputs = inputs_for("tinyvgg", 2, 920);
    let want = local_refs("tinyvgg", &inputs);
    let mut faults: Vec<WorkerFaults> = (0..3).map(|_| WorkerFaults::none()).collect();
    faults[0] = WorkerFaults::none().stalls_in(0..4096);
    let (server, workers) = spawn(base_config(SchemeKind::Uncoded, 3), 3, faults);
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, m) = h.wait().expect("request wedged behind a stalled worker");
        assert_eq!(out.data, want.data, "hedged uncoded output not bitwise-local");
        assert!(m.hedges() >= 1, "no hedge fired against the stalled worker");
        assert_eq!(
            m.fallbacks(),
            0,
            "the hedge should complete the round before the fallback timer"
        );
    }
    let stats = server.stats();
    assert_eq!(stats.completed, inputs.len() as u64);
    assert_eq!(stats.failed, 0);
    // The registry's event log agrees with the per-request metrics.
    let master = server.shutdown().unwrap();
    assert!(master.telemetry_json().req_f64("hedges").unwrap() >= 1.0);
    master.shutdown();
    workers.join().unwrap();
}

/// Total pool collapse: every worker stalls every round, so no hedge
/// target can help (hedging is disabled to pin the fallback path
/// alone). The master must compute the missing shards locally and
/// complete the decode — bitwise on the uncoded path.
#[test]
fn pool_collapse_completes_via_local_fallback() {
    let inputs = inputs_for("tinyvgg", 1, 921);
    let want = local_refs("tinyvgg", &inputs);
    let faults: Vec<WorkerFaults> = (0..3)
        .map(|_| WorkerFaults::none().stalls_in(0..4096))
        .collect();
    let mut config = base_config(SchemeKind::Uncoded, 3);
    config.hedge_quantile = 0.0;
    let (server, workers) = spawn(config, 3, faults);
    let h = server.submit(InferenceRequest::new(inputs[0].clone())).unwrap();
    let (out, m) = h.wait().expect("request wedged on a fully-stalled pool");
    assert_eq!(out.data, want[0].data, "fallback output not bitwise-local");
    assert!(m.fallbacks() >= 1, "master never took a shard over locally");
    let master = server.shutdown().unwrap();
    assert!(master.telemetry_json().req_f64("fallbacks").unwrap() >= 1.0);
    master.shutdown();
    workers.join().unwrap();
}

/// Pool collapse under MDS: the locally-computed shards feed the same
/// decoder a worker reply would, so the decoded output stays within
/// decode tolerance of local inference.
#[test]
fn pool_collapse_mds_within_tolerance() {
    let inputs = inputs_for("tinyvgg", 1, 924);
    let want = local_refs("tinyvgg", &inputs);
    let faults: Vec<WorkerFaults> = (0..4)
        .map(|_| WorkerFaults::none().stalls_in(0..4096))
        .collect();
    let mut config = base_config(SchemeKind::Mds, 3);
    config.hedge_quantile = 0.0;
    let (server, workers) = spawn(config, 4, faults);
    let h = server.submit(InferenceRequest::new(inputs[0].clone())).unwrap();
    let (out, m) = h.wait().expect("MDS request wedged on a fully-stalled pool");
    let err = out.max_abs_diff(&want[0]);
    assert!(err < 2e-2, "MDS fallback output off local by {err}");
    assert!(m.fallbacks() >= 1);
    stop(server, workers);
}

/// No faults ⇒ no speculation: the watchdog's floor keeps ms-scale
/// subtasks far below the hedge threshold, so a healthy run reports
/// zero hedges and zero fallbacks (the no-false-positive contract).
#[test]
fn fault_free_run_never_speculates() {
    let inputs = inputs_for("tinyvgg", 4, 922);
    let want = local_refs("tinyvgg", &inputs);
    let (server, workers) = spawn(
        base_config(SchemeKind::Mds, 3),
        4,
        (0..4).map(|_| WorkerFaults::none()).collect(),
    );
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, m) = h.wait().unwrap();
        let err = out.max_abs_diff(want);
        assert!(err < 2e-2, "healthy run off local by {err}");
        assert_eq!(m.hedges(), 0, "hedge fired on a healthy pool");
        assert_eq!(m.fallbacks(), 0, "fallback fired on a healthy pool");
    }
    stop(server, workers);
}

/// Storm-cap accounting regression: the retry budget is per *round*
/// (`retry_budget × subtasks`), not read off a coalesced part's metrics
/// counter. A worker failing every round inside coalesced rounds burns
/// one retry per round — far inside budget — and every merged request
/// stays bitwise-correct with no fallback needed.
#[test]
fn coalesced_rounds_survive_persistent_failures() {
    let inputs = inputs_for("tinyvgg", 8, 923);
    let want = local_refs("tinyvgg", &inputs);
    let mut faults: Vec<WorkerFaults> = (0..3).map(|_| WorkerFaults::none()).collect();
    faults[0] = WorkerFaults::none().fails_in(0..4096);
    let mut config = base_config(SchemeKind::Uncoded, 3);
    config.coalesce = 4;
    let (server, workers) = spawn(config, 3, faults);
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, m) = h.wait().expect("coalesced request failed under persistent failures");
        assert_eq!(out.data, want.data, "coalesced chaos output not bitwise-local");
        assert!(m.redispatches() >= 1, "the failing worker was never retried");
        assert_eq!(m.fallbacks(), 0, "retries within budget must not escalate");
    }
    assert_eq!(server.stats().completed, inputs.len() as u64);
    stop(server, workers);
}

/// Budget exhaustion escalates instead of erroring: with a zero retry
/// budget, a failed shard cannot be re-dispatched — the old engine
/// bailed with "re-dispatch storm" — and is handed to the master-local
/// fallback, so the request still completes bitwise.
#[test]
fn exhausted_retry_budget_escalates_to_fallback() {
    let inputs = inputs_for("tinyvgg", 2, 925);
    let want = local_refs("tinyvgg", &inputs);
    let mut faults: Vec<WorkerFaults> = (0..3).map(|_| WorkerFaults::none()).collect();
    faults[0] = WorkerFaults::none().fails_in(0..4096);
    let mut config = base_config(SchemeKind::Uncoded, 3);
    config.coalesce = 2;
    config.retry_budget = 0;
    let (server, workers) = spawn(config, 3, faults);
    let handles: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        let (out, m) = h.wait().expect("request failed instead of escalating to fallback");
        assert_eq!(out.data, want.data, "escalated output not bitwise-local");
        assert!(m.fallbacks() >= 1, "exhausted budget must escalate to the fallback");
    }
    stop(server, workers);
}
