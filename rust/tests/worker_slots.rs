//! Intra-worker concurrency semantics at the engine level, plus the
//! telemetry-normalization pin for coalesced execution.
//!
//! The worker's persistent executor (`--worker-slots`) must be a pure
//! scheduling change: exactly one reply per dispatched subtask at every
//! slot count (pinned at the wire level in `coordinator::worker` unit
//! tests), bitwise-identical outputs through the full engine, and
//! telemetry fits that cannot tell a coalesced batch from a
//! single-request conv (exec time is normalized by coalesced FLOPs).

use std::sync::Arc;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, LocalCluster, MasterConfig, PoolOptions, SchemeKind, WorkerFaults,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn inputs_for(count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn spawn(
    scheme: SchemeKind,
    n: usize,
    k: usize,
    mode: ExecMode,
    coalesce: usize,
    worker_slots: usize,
) -> LocalCluster {
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(k),
        mode,
        coalesce,
        ..Default::default()
    };
    LocalCluster::spawn_with(
        "tinyvgg",
        n,
        config,
        Arc::new(FallbackProvider::new()),
        (0..n).map(|_| WorkerFaults::none()).collect(),
        PoolOptions { worker_slots },
    )
    .unwrap()
}

/// Engine-level slot sweep: the pipelined batch over 1/2/4-slot workers
/// is bitwise-identical to local inference on the uncoded path — the
/// executor changes *when* subtasks run, never what they compute, and
/// the engine's round accounting absorbs out-of-order completions.
#[test]
fn slot_sweep_outputs_bitwise_local() {
    let inputs = inputs_for(4, 641);
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let want: Vec<Tensor> = inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect();
    for slots in [1, 2, 4] {
        let mut c = spawn(SchemeKind::Uncoded, 3, 3, ExecMode::Pipelined, 1, slots);
        let outs = c.master.infer_batch(&inputs).unwrap();
        for ((out, _), want) in outs.iter().zip(&want) {
            assert_eq!(out.data, want.data, "slots={slots}: output not bitwise-local");
        }
        c.shutdown().unwrap();
    }
}

/// Multi-slot MDS under straggler cancellation: cancels are acked
/// exactly once per dispatched subtask even when several convs are in
/// flight per device, so the batch drains with exact accounting (a
/// double- or zero-ack would wedge the engine's load bookkeeping and
/// time the run out).
#[test]
fn multislot_cancellation_accounting_drains() {
    let inputs = inputs_for(6, 642);
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(2),
        mode: ExecMode::Pipelined,
        coalesce: 3,
        ..Default::default()
    };
    // One slow link forces routine mid-round cancellation.
    let mut faults: Vec<WorkerFaults> = (0..4).map(|_| WorkerFaults::none()).collect();
    faults[1] = WorkerFaults::with_send_delay(0.02);
    let mut c = LocalCluster::spawn_with(
        "tinyvgg",
        4,
        config,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots: 4 },
    )
    .unwrap();
    let results = c.master.infer_batch(&inputs).unwrap();
    for ((out, _), input) in results.iter().zip(&inputs) {
        let want = forward_local(&model, &weights, input).unwrap();
        let err = out.max_abs_diff(&want);
        assert!(err < 2e-2, "multislot cancellation run off local by {err}");
    }
    c.shutdown().unwrap();
}

/// Median fitted per-FLOP execution time across the pool.
fn median_cmp_mean(cluster: &LocalCluster) -> f64 {
    let reg = cluster.master.registry();
    let mut means: Vec<f64> = (0..reg.n_workers())
        .filter_map(|w| reg.estimate(w))
        .map(|est| est.cmp.mean())
        .collect();
    assert!(!means.is_empty(), "no fitted workers — not enough samples");
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    means[means.len() / 2]
}

/// The telemetry-normalization pin: coalesced rounds report ONE
/// exec_secs for the whole batched conv, and the master normalizes it
/// by the round's *coalesced* FLOPs. The fitted per-FLOP execution
/// scale must therefore land where the single-request fit lands; a
/// normalization bug would inflate (or deflate) it by roughly the
/// coalescing factor (4x here), far outside this window.
#[test]
fn coalesced_exec_normalization_keeps_cmp_fit_unbiased() {
    // Enough requests that even a model plan with few distributed
    // layers clears the registry's min-sample bar on the coalesced run
    // (20 requests / coalesce 4 = 5 rounds per distributed layer).
    let inputs = inputs_for(20, 643);
    // Single-request engine: one payload per round.
    let mut solo = spawn(SchemeKind::Uncoded, 3, 3, ExecMode::Pipelined, 1, 1);
    solo.master.infer_batch(&inputs).unwrap();
    let solo_mean = median_cmp_mean(&solo);
    solo.shutdown().unwrap();

    // Coalesced engine: the batch rides multi-payload rounds.
    let mut coal = spawn(SchemeKind::Uncoded, 3, 3, ExecMode::Pipelined, 4, 1);
    coal.master.infer_batch(&inputs).unwrap();
    let coal_mean = median_cmp_mean(&coal);
    coal.shutdown().unwrap();

    let ratio = coal_mean / solo_mean;
    assert!(
        (0.4..2.5).contains(&ratio),
        "coalesced per-FLOP fit {coal_mean:e} vs solo {solo_mean:e} \
         (ratio {ratio:.2}): normalization biased"
    );
}
