//! Multi-tenant fair-serving suite: per-tenant admission quotas
//! (`SubmitError::TenantQuota`), deficit-round-robin dispatch across
//! weighted tenant queues (EDF inside each tenant's turn), the
//! no-starvation property the DRR schedule exists for, deadline-aware
//! coalescing (a tight-deadline request rides alone), and the
//! tenant-labelled scrape families. Runs without `artifacts/`.

use std::sync::Arc;
use std::time::Duration;

use cocoi::conv::Tensor;
use cocoi::coordinator::fair::{tight_deadline, DrrQueue};
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, PoolOptions,
    SchemeKind, ServerConfig, SubmitError, WorkerFaults, WorkerHandles,
};
use cocoi::latency::SystemProfile;
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::sim::{
    simulate_serving_open, simulate_serving_tenants, MethodSim, Scenario, ServeSimMode,
    TenantLoad,
};
use cocoi::util::Rng;

fn inputs_for(count: usize, seed: u64) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let mut t = Tensor::zeros(model.input.0, model.input.1, model.input.2);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            t
        })
        .collect()
}

fn local_refs(inputs: &[Tensor]) -> Vec<Tensor> {
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    inputs
        .iter()
        .map(|i| forward_local(&model, &weights, i).unwrap())
        .collect()
}

fn spawn_server(
    master_cfg: MasterConfig,
    server_cfg: ServerConfig,
    faults: Vec<WorkerFaults>,
) -> (InferenceServer, WorkerHandles) {
    let n = faults.len();
    let cluster = LocalCluster::spawn_with(
        "tinyvgg",
        n,
        master_cfg,
        Arc::new(FallbackProvider::new()),
        faults,
        PoolOptions { worker_slots: 1 },
    )
    .unwrap();
    let (master, workers) = cluster.into_parts();
    (InferenceServer::start(master, server_cfg), workers)
}

fn stop(server: InferenceServer, workers: WorkerHandles) {
    let master = server.shutdown().unwrap();
    master.shutdown();
    workers.join().unwrap();
}

/// DRR weights are respected within one rotation round: with weights
/// a:2, b:1 and both tenants backlogged, the steady-state pop pattern
/// is a,a,b — tenant a gets exactly twice tenant b's service, never a
/// long unfair burst.
#[test]
fn drr_weights_respected_within_one_round() {
    let mut q: DrrQueue<i64> = DrrQueue::new(&[("a".to_string(), 2.0), ("b".to_string(), 1.0)]);
    for i in 0..6 {
        q.push("a", 100 - i); // descending: heap order == insertion order
        q.push("b", 200 - i);
    }
    let mut owners = Vec::new();
    while let Some(v) = q.pop() {
        owners.push(if v >= 195 { 'b' } else { 'a' });
    }
    assert_eq!(
        owners,
        vec!['a', 'a', 'b', 'a', 'a', 'b', 'a', 'a', 'b', 'b', 'b', 'b'],
        "weights 2:1 must yield the a,a,b rotation until a drains"
    );
}

/// EDF inside a tenant's turn: within one tenant, pops follow the
/// caller's `Ord` (here: plain max-heap order), independent of push
/// order.
#[test]
fn edf_order_inside_each_turn() {
    let mut q: DrrQueue<i64> = DrrQueue::new(&[]);
    for x in [3, 9, 1, 7] {
        q.push("solo", x);
    }
    let mut got = Vec::new();
    while let Some(x) = q.pop() {
        got.push(x);
    }
    assert_eq!(got, vec![9, 7, 3, 1]);
}

/// THE no-starvation property, at sim scale (the serving experiment's
/// fifth hard gate): a trickle victim (0.25x capacity, weight 16) next
/// to a flooding tenant keeps near-isolated tail latency under fair
/// sharing, while the pre-tenancy FIFO queue starves it. Per-tenant rng
/// seeds make the victim's draws bitwise-identical across all three
/// arms, so the comparison is pure scheduling interference.
#[test]
fn no_starvation_under_flood() {
    let model = zoo::model("vgg16").unwrap();
    let p = SystemProfile::paper_default();
    let scenario = Scenario::None;
    // Mean isolated service time fixes the load scale.
    let service = {
        let mut rng = Rng::new(0x5E21);
        let r = simulate_serving_open(
            &model,
            &p,
            10,
            MethodSim::CocoiKCirc,
            scenario,
            ServeSimMode::Barrier,
            1e-9,
            16,
            None,
            &mut rng,
        )
        .unwrap();
        r.latencies.iter().sum::<f64>() / r.latencies.len() as f64
    };
    let victim = TenantLoad {
        name: "victim".into(),
        rate: 0.25 / service,
        weight: 16.0,
        seed: 0xF00D1,
    };
    let flooder = TenantLoad {
        name: "flooder".into(),
        rate: 1.3 / service,
        weight: 1.0,
        seed: 0xF00D2,
    };
    let horizon = 40.0 * service;
    let run = |loads: &[TenantLoad], fair: bool| {
        simulate_serving_tenants(
            &model,
            &p,
            10,
            MethodSim::CocoiKCirc,
            scenario,
            loads,
            horizon,
            None,
            fair,
        )
        .unwrap()
    };
    let iso = run(std::slice::from_ref(&victim), true);
    let fair = run(&[victim.clone(), flooder.clone()], true);
    let fifo = run(&[victim, flooder], false);
    assert!(iso[0].arrivals > 0 && iso[0].latencies.len() == iso[0].arrivals);
    // Same private stream → same offered trace in every arm.
    assert_eq!(fair[0].arrivals, iso[0].arrivals);
    assert_eq!(fifo[0].arrivals, iso[0].arrivals);
    // The gate: fair-shared victim p95 within 1.2x of isolated; and
    // EVERY victim request completes within a small multiple of the
    // worst isolated sojourn (no one starves, not just the p95).
    assert!(
        fair[0].p95() <= 1.2 * iso[0].p95(),
        "fair victim p95 {} > 1.2x isolated {}",
        fair[0].p95(),
        iso[0].p95()
    );
    let iso_max = iso[0].latencies.iter().cloned().fold(0.0, f64::max);
    let fair_max = fair[0].latencies.iter().cloned().fold(0.0, f64::max);
    assert!(
        fair_max <= 1.5 * iso_max,
        "worst fair victim sojourn {fair_max} > 1.5x worst isolated {iso_max}"
    );
    // The FIFO baseline is what the gate rules out: the flooder's
    // backlog buries the victim.
    assert!(
        fifo[0].p95() > 1.2 * iso[0].p95(),
        "FIFO victim p95 {} unexpectedly within the fair bound {}",
        fifo[0].p95(),
        iso[0].p95()
    );
}

/// Live DRR dispatch order: with a serial engine (max_concurrent 1) and
/// a backlogged flooder, a weighted victim's requests are served ahead
/// of the flooder's later backlog — engine-stamped sojourns expose the
/// service order.
#[test]
fn weighted_tenant_overtakes_flooder_backlog() {
    let inputs = inputs_for(6, 941);
    // 20 ms per reply keeps the engine busy while the burst queues up.
    let faults: Vec<WorkerFaults> = (0..3)
        .map(|_| WorkerFaults::with_send_delay(0.020))
        .collect();
    let (server, workers) = spawn_server(
        MasterConfig {
            scheme: SchemeKind::Uncoded,
            policy: SplitPolicy::Fixed(3),
            mode: ExecMode::Pipelined,
            tenant_weights: vec![("victim".to_string(), 4.0), ("flooder".to_string(), 1.0)],
            ..Default::default()
        },
        ServerConfig {
            max_concurrent: 1,
            ..Default::default()
        },
        faults,
    );
    // f0 occupies the engine; f1..f3 then v1, v2 queue behind it.
    let f: Vec<_> = inputs[..4]
        .iter()
        .map(|i| {
            server
                .submit(InferenceRequest::new(i.clone()).with_tenant("flooder"))
                .unwrap()
        })
        .collect();
    let v: Vec<_> = inputs[4..]
        .iter()
        .map(|i| {
            server
                .submit(InferenceRequest::new(i.clone()).with_tenant("victim"))
                .unwrap()
        })
        .collect();
    let settle = |h: cocoi::coordinator::RequestHandle| -> f64 {
        let (res, sojourn) = h.wait_timed();
        res.expect("request failed");
        sojourn.as_secs_f64()
    };
    let f_sojourns: Vec<f64> = f.into_iter().map(settle).collect();
    let v_sojourns: Vec<f64> = v.into_iter().map(settle).collect();
    // DRR with weights {victim: 4, flooder: 1} serves f0, f1, v1, v2,
    // f2, f3 — both victim requests complete before the flooder's last
    // two, despite being submitted after them.
    for (vi, vs) in v_sojourns.iter().enumerate() {
        for (fi, fs) in f_sojourns.iter().enumerate().skip(2) {
            assert!(
                vs < fs,
                "victim {vi} (sojourn {vs:.3}s) should beat flooder {fi} ({fs:.3}s)"
            );
        }
    }
    // The tenant-labelled scrape families carry the per-tenant counts.
    let prom = server.scrape().to_prometheus();
    assert!(prom.contains("cocoi_tenant_submitted_total{tenant=\"flooder\"} 4"));
    assert!(prom.contains("cocoi_tenant_submitted_total{tenant=\"victim\"} 2"));
    assert!(prom.contains("cocoi_tenant_completed_total{tenant=\"victim\"} 2"));
    assert!(prom.contains("cocoi_tenant_open_requests{tenant=\"victim\"} 0"));
    stop(server, workers);
}

/// Per-tenant admission quota: the third open request of a tenant is
/// refused with `TenantQuota`, other tenants are unaffected, and the
/// slot frees once a request completes.
#[test]
fn tenant_quota_bounds_open_requests() {
    let inputs = inputs_for(5, 942);
    let want = local_refs(&inputs);
    let faults: Vec<WorkerFaults> = (0..3)
        .map(|_| WorkerFaults::with_send_delay(0.020))
        .collect();
    let (server, workers) = spawn_server(
        MasterConfig {
            scheme: SchemeKind::Uncoded,
            policy: SplitPolicy::Fixed(3),
            mode: ExecMode::Pipelined,
            ..Default::default()
        },
        ServerConfig {
            tenant_quota: 2,
            ..Default::default()
        },
        faults,
    );
    let a1 = server
        .submit(InferenceRequest::new(inputs[0].clone()).with_tenant("acme"))
        .unwrap();
    let a2 = server
        .submit(InferenceRequest::new(inputs[1].clone()).with_tenant("acme"))
        .unwrap();
    // Third open "acme" request: over quota.
    match server.submit(InferenceRequest::new(inputs[2].clone()).with_tenant("acme")) {
        Err(SubmitError::TenantQuota) => {}
        other => panic!("expected TenantQuota, got {:?}", other.map(|h| h.id())),
    }
    assert_eq!(server.stats().rejected_tenant_quota, 1);
    // A different tenant is not collateral damage.
    let b1 = server
        .submit(InferenceRequest::new(inputs[3].clone()).with_tenant("bravo"))
        .unwrap();
    // In-flight requests complete correctly despite the rejection.
    for (h, want) in [(a1, &want[0]), (a2, &want[1]), (b1, &want[3])] {
        let (out, _) = h.wait().unwrap();
        assert_eq!(out.data, want.data);
    }
    // Quota freed: "acme" submits again.
    let a3 = server
        .submit(InferenceRequest::new(inputs[2].clone()).with_tenant("acme"))
        .unwrap();
    let (out, _) = a3.wait().unwrap();
    assert_eq!(out.data, want[2].data);
    let prom = server.scrape().to_prometheus();
    assert!(prom.contains("cocoi_tenant_quota_rejections_total{tenant=\"acme\"} 1"));
    stop(server, workers);
}

/// Deadline-aware coalescing pin. Policy level: a request whose slack
/// is under `TIGHT_SLACK_MULTIPLE` x the predicted service time is
/// tight and must ride alone. Engine level: a tight-deadline request
/// submitted into a wide coalescing burst still completes bitwise-
/// correctly and inside its deadline — it was dispatched as a closed
/// singleton round, with the burst coalescing around it.
#[test]
fn tight_deadline_rides_alone_through_coalescing() {
    // The policy itself (mirrors `fair::tight_deadline`'s contract).
    assert!(tight_deadline(Some(1.0), Some(0.5)));
    assert!(!tight_deadline(Some(10.0), Some(0.5)));
    assert!(!tight_deadline(None, Some(0.5)));

    let inputs = inputs_for(4, 943);
    let want = local_refs(&inputs);
    let (server, workers) = spawn_server(
        MasterConfig {
            scheme: SchemeKind::Uncoded,
            policy: SplitPolicy::Fixed(3),
            mode: ExecMode::Pipelined,
            coalesce: 4,
            ..Default::default()
        },
        ServerConfig::default(),
        (0..3).map(|_| WorkerFaults::none()).collect(),
    );
    // Three wide (no-deadline) requests + one tight-deadline request.
    // With the unfitted 0.5 s service floor, a 1 s deadline is tight
    // (slack < 4 x 0.5 s) yet generous against tinyvgg's ~ms service —
    // it must complete, not shed, and not sit behind a wide batch.
    let wide: Vec<_> = inputs[..3]
        .iter()
        .map(|i| server.submit(InferenceRequest::new(i.clone())).unwrap())
        .collect();
    let tight = server
        .submit(
            InferenceRequest::new(inputs[3].clone())
                .with_deadline(Duration::from_secs(1)),
        )
        .unwrap();
    let (out, _) = tight.wait().expect("tight-deadline request must not shed or wedge");
    assert_eq!(out.data, want[3].data);
    for (h, want) in wide.into_iter().zip(&want) {
        let (out, _) = h.wait().unwrap();
        assert_eq!(out.data, want.data);
    }
    stop(server, workers);
}
