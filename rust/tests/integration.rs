//! Cross-module integration tests: the python-AOT → PJRT → coordinator
//! round trip, the TCP deployment path, and end-to-end distributed
//! inference through real artifacts.
//!
//! Tests that need `artifacts/` (built by `make artifacts`) skip with a
//! message when it is absent so plain `cargo test` stays green.

use std::path::PathBuf;
use std::sync::Arc;

use cocoi::conv::{ConvSpec, Tensor};
use cocoi::coordinator::worker::{run_worker, WorkerConfig};
use cocoi::coordinator::{LocalCluster, MasterConfig, SchemeKind, WorkerFaults};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::{ConvProvider, FallbackProvider, Manifest, PjrtProvider, PjrtService};
use cocoi::transport::split::split_tcp;
use cocoi::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("COCOI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

/// The AOT bridge: a fused conv artifact must reproduce the pure-rust
/// conv on random inputs.
#[test]
fn pjrt_fused_conv_matches_fallback() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let service = PjrtService::spawn().unwrap();
    let provider = PjrtProvider::new(service.handle(), manifest.clone());

    let mut rng = Rng::new(123);
    let mut checked = 0;
    for (key, _) in manifest.conv.iter().take(6) {
        let spec = ConvSpec::new(key.c_in, key.c_out, key.k_w, key.s_w, 0);
        let mut input = Tensor::zeros(key.c_in, key.h_i, key.w_i_p);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut weights = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut weights, -0.5, 0.5);

        let via_pjrt = provider.conv(&spec, &input, &weights).unwrap();
        let via_rust = FallbackProvider::new().conv(&spec, &input, &weights).unwrap();
        assert_eq!(via_pjrt.shape(), via_rust.shape());
        let err = via_pjrt.max_abs_diff(&via_rust);
        assert!(err < 1e-3, "artifact {key:?} differs from fallback by {err}");
        checked += 1;
    }
    assert!(checked > 0);
    assert!(provider.stats.fused.load(std::sync::atomic::Ordering::Relaxed) >= checked);
}

/// The shape-polymorphic GEMM-tile path must agree with the fallback for
/// a shape that has NO fused artifact.
#[test]
fn pjrt_tile_provider_matches_fallback() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let service = PjrtService::spawn().unwrap();
    let provider = PjrtProvider::new(service.handle(), manifest);

    // Odd shape not in the manifest (h_i = 23).
    let spec = ConvSpec::new(5, 7, 3, 1, 0);
    let mut rng = Rng::new(321);
    let mut input = Tensor::zeros(5, 23, 19);
    rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let mut weights = vec![0f32; spec.weight_len()];
    rng.fill_uniform_f32(&mut weights, -0.5, 0.5);

    let got = provider.conv(&spec, &input, &weights).unwrap();
    let want = FallbackProvider::new().conv(&spec, &input, &weights).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-3);
    assert_eq!(
        provider.stats.tiled.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "must have taken the tiled path"
    );
}

/// Full distributed inference where every worker executes through PJRT
/// artifacts — the end-to-end three-layer claim.
#[test]
fn distributed_inference_via_pjrt_matches_local() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let service = PjrtService::spawn().unwrap();
    let provider: Arc<dyn ConvProvider> =
        Arc::new(PjrtProvider::new(service.handle(), manifest));

    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let mut input = Tensor::zeros(3, 56, 56);
    Rng::new(5).fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let want = forward_local(&model, &weights, &input).unwrap();

    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(3),
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        4,
        config,
        provider,
        (0..4).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let (got, metrics) = cluster.master.infer(&input).unwrap();
    cluster.shutdown().unwrap();

    assert_eq!(got.shape(), want.shape());
    let err = got.max_abs_diff(&want);
    assert!(err < 2e-2, "PJRT distributed differs from local by {err}");
    assert!(metrics.layers.iter().any(|l| l.distributed));
}

/// TCP deployment: master and worker over a real socket.
#[test]
fn tcp_worker_end_to_end() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let worker_thread = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let (tx, rx) = split_tcp(stream).unwrap();
        run_worker(
            Box::new(tx),
            Box::new(rx),
            WorkerConfig {
                id: 0,
                provider: Arc::new(FallbackProvider::new()),
                faults: WorkerFaults::none(),
                rng_seed: 1,
                slots: 1,
                trace: None,
            },
        )
        .unwrap();
    });

    let stream = std::net::TcpStream::connect(addr).unwrap();
    let (tx, rx) = split_tcp(stream).unwrap();
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(1),
        ..Default::default()
    };
    let mut master = cocoi::coordinator::Master::new(
        "tinyvgg",
        config,
        vec![(Box::new(tx), Box::new(rx))],
        Arc::new(FallbackProvider::new()),
    )
    .unwrap();

    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let mut input = Tensor::zeros(3, 56, 56);
    Rng::new(77).fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let want = forward_local(&model, &weights, &input).unwrap();
    let (got, _) = master.infer(&input).unwrap();
    master.shutdown();
    worker_thread.join().unwrap();

    assert!(got.max_abs_diff(&want) < 2e-2);
}

/// Property-style: distributed == local across schemes, split sizes, and
/// worker counts (beyond the fixed cases in the unit suite).
#[test]
fn distributed_matches_local_across_configs() {
    let model = zoo::model("tinyresnet").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let mut rng = Rng::new(31);
    for (scheme, n, k) in [
        (SchemeKind::Mds, 2, 1),
        (SchemeKind::Mds, 5, 4),
        (SchemeKind::Uncoded, 3, 3),
        (SchemeKind::Replication, 5, 2),
        (SchemeKind::LtCoarse, 3, 2),
    ] {
        let mut input = Tensor::zeros(3, 56, 56);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let want = forward_local(&model, &weights, &input).unwrap();
        let config = MasterConfig {
            scheme,
            policy: SplitPolicy::Fixed(k),
            ..Default::default()
        };
        let mut cluster = LocalCluster::spawn(
            "tinyresnet",
            n,
            config,
            Arc::new(FallbackProvider::new()),
            (0..n).map(|_| WorkerFaults::none()).collect(),
        )
        .unwrap();
        let (got, _) = cluster.master.infer(&input).unwrap();
        cluster.shutdown().unwrap();
        let err = got.max_abs_diff(&want);
        assert!(err < 2e-2, "{scheme:?} n={n} k={k}: err {err}");
    }
}
