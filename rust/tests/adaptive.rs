//! Adaptive-replanning regressions (the telemetry tentpole's acceptance
//! tests), all on fixed seeds like `sim_regression.rs`:
//!
//! * fitted μ_cmp converges to the drifted truth (within 15%),
//! * the adaptive plan strictly beats the static calibrated plan on the
//!   drifting-capacity scenario,
//! * quarantine + probe reintegration round-trips a failed worker,
//! * with no drift, hysteresis keeps the adaptive run *bitwise
//!   identical* to the static one (no plan thrash),
//! * traces are bitwise reproducible run over run.
//!
//! End-to-end (real coordinator, in-proc workers): the adaptive master
//! still reproduces local inference and produces per-worker telemetry.

use std::sync::Arc;

use cocoi::coordinator::{
    ExecMode, LocalCluster, MasterConfig, SchemeKind, WorkerFaults,
};
use cocoi::latency::SystemProfile;
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::sim::{simulate_adaptive, AdaptiveSimResult, DriftScenario};
use cocoi::telemetry::EventKind;
use cocoi::util::Rng;

const N: usize = 10;

fn run(drift: DriftScenario, n_req: usize, adaptive: bool, seed: u64) -> AdaptiveSimResult {
    let model = zoo::model("vgg16").unwrap();
    let p = SystemProfile::paper_default();
    let mut rng = Rng::new(seed);
    simulate_adaptive(&model, &p, N, drift, n_req, adaptive, 4, &mut rng).unwrap()
}

/// (a) Under a pool-wide 3x compute slowdown the per-worker fits keep
/// sampling (no quarantine: scores stay relative to the pool median),
/// and the fitted pool μ_cmp converges to the drifted truth μ/3.
#[test]
fn fitted_mu_cmp_converges_to_drifted_rate() {
    let p = SystemProfile::paper_default();
    let res = run(
        DriftScenario::ComputeSlowdown { m: N, factor: 3.0, at: 4 },
        40,
        true,
        42,
    );
    // Uniform drift must not quarantine anybody.
    assert!(
        res.events.is_empty(),
        "uniform drift should not quarantine: {:?}",
        res.events
    );
    let fitted = res.registry.fitted_profile(&p);
    let true_mu = p.mu_cmp / 3.0;
    let rel = (fitted.mu_cmp - true_mu).abs() / true_mu;
    assert!(rel < 0.15, "fitted mu_cmp {:.3e} vs true {true_mu:.3e} (rel {rel:.3})", fitted.mu_cmp);
    // θ stretches with the wall-time slowdown too.
    let true_theta = p.theta_cmp * 3.0;
    let rel_t = (fitted.theta_cmp - true_theta).abs() / true_theta;
    assert!(rel_t < 0.10, "fitted theta_cmp rel err {rel_t:.3}");
}

/// (b) Three workers slowing down 3x overwhelms the static plan's
/// redundancy (k°=8 of n=10 absorbs only two); the adaptive policy
/// quarantines them, re-solves for the shrunken pool, and wins the
/// post-drift window outright. Common random numbers (same seed) make
/// the comparison noise-free.
#[test]
fn adaptive_beats_static_under_drift() {
    let drift = DriftScenario::ComputeSlowdown { m: 3, factor: 3.0, at: 8 };
    let stat = run(drift, 32, false, 7);
    let adap = run(drift, 32, true, 7);
    let stat_mean = stat.mean_from(16);
    let adap_mean = adap.mean_from(16);
    assert!(
        adap_mean < stat_mean,
        "adaptive {adap_mean:.2}s must beat static {stat_mean:.2}s"
    );
    // The python-prototyped margin is ~0.85-0.90; leave headroom.
    assert!(
        adap_mean < 0.97 * stat_mean,
        "win too thin: {adap_mean:.2}s vs {stat_mean:.2}s"
    );
    assert!(adap.switches >= 1, "expected at least one plan swap");
    assert!(
        adap.events.iter().any(|e| e.kind == EventKind::QuarantineSlow),
        "expected straggler quarantines: {:?}",
        adap.events
    );
    // The static policy never switches or quarantines.
    assert_eq!(stat.switches, 0);
    assert!(stat.events.is_empty());
}

/// (c) A worker that dies and later returns is quarantined on
/// consecutive failures, probed while down, and reintegrated once its
/// probes succeed — and the adaptive run stays within noise of static.
#[test]
fn quarantine_and_reintegration_roundtrip() {
    let drift = DriftScenario::DieAndReturn { worker: 2, down_at: 6, up_at: 18 };
    let adap = run(drift, 32, true, 11);
    let quarantined_at = adap
        .events
        .iter()
        .position(|e| e.kind == EventKind::QuarantineFail && e.worker == 2)
        .expect("worker 2 must be quarantined after consecutive failures");
    let reintegrated_at = adap
        .events
        .iter()
        .position(|e| e.kind == EventKind::Reintegrate && e.worker == 2)
        .expect("worker 2 must be reintegrated after it returns");
    assert!(quarantined_at < reintegrated_at);
    assert!(!adap.registry.is_quarantined(2), "round-trip must complete");
    let stat = run(drift, 32, false, 11);
    assert!(
        adap.mean() <= 1.05 * stat.mean(),
        "adaptive {:.2}s vs static {:.2}s",
        adap.mean(),
        stat.mean()
    );
}

/// With stationary capacities the hysteresis must hold the incumbent
/// plan: no swaps, no quarantines — and because the sim draws on common
/// random numbers, the adaptive trace is bitwise identical to static.
#[test]
fn no_drift_no_thrash_bitwise() {
    let stat = run(DriftScenario::None, 16, false, 21);
    let adap = run(DriftScenario::None, 16, true, 21);
    assert_eq!(adap.switches, 0, "plan thrash with no drift");
    assert!(adap.events.is_empty());
    assert_eq!(adap.final_ks, stat.final_ks);
    for (i, (a, s)) in adap.latencies.iter().zip(&stat.latencies).enumerate() {
        assert_eq!(
            a.to_bits(),
            s.to_bits(),
            "request {i}: adaptive {a} != static {s}"
        );
    }
}

/// Fixed seed => bitwise-identical trace, drift or not (the
/// sim_regression.rs contract extended to the adaptive loop).
#[test]
fn adaptive_traces_are_reproducible() {
    for drift in [
        DriftScenario::None,
        DriftScenario::ComputeSlowdown { m: 3, factor: 3.0, at: 8 },
        DriftScenario::DieAndReturn { worker: 2, down_at: 6, up_at: 18 },
        DriftScenario::TransmissionCongestion { factor: 30.0, at: 8 },
    ] {
        let a = run(drift, 20, true, 5);
        let b = run(drift, 20, true, 5);
        assert_eq!(a.latencies.len(), b.latencies.len());
        for (x, y) in a.latencies.iter().zip(&b.latencies) {
            assert_eq!(x.to_bits(), y.to_bits(), "{drift:?}");
        }
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.events, b.events);
    }
}

/// End-to-end on the real coordinator: an adaptive master (pipelined
/// engine, in-proc pool) still reproduces local inference bit-for-bit
/// within tolerance, collects per-worker phase telemetry, and exposes a
/// well-formed telemetry dump.
#[test]
fn adaptive_master_end_to_end() {
    let model = zoo::model("tinyvgg").unwrap();
    let weights = WeightStore::generate(&model, 42).unwrap();
    let mut input = cocoi::conv::Tensor::zeros(3, 56, 56);
    Rng::new(33).fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let want = forward_local(&model, &weights, &input).unwrap();

    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(3),
        mode: ExecMode::Pipelined,
        adaptive: true,
        ..Default::default()
    };
    let n = 4;
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        n,
        config,
        Arc::new(FallbackProvider::new()),
        (0..n).map(|_| WorkerFaults::none()).collect(),
    )
    .unwrap();
    let inputs = vec![input.clone(), input.clone()];
    let results = cluster.master.infer_batch(&inputs).unwrap();
    assert_eq!(results.len(), 2);
    for (got, metrics) in &results {
        assert_eq!(got.shape(), want.shape());
        let err = got.max_abs_diff(&want);
        assert!(err < 2e-2, "adaptive output differs from local by {err}");
        // Per-worker breakdown present on distributed layers, and the
        // decomposition is sane (nonnegative, bounded by the round).
        let dist = metrics.layers.iter().find(|l| l.distributed).unwrap();
        assert!(!dist.per_worker.is_empty());
        for wp in &dist.per_worker {
            assert!(wp.worker < n);
            assert!(wp.execution >= 0.0 && wp.transmission >= 0.0);
        }
    }

    // Telemetry dump carries one entry per worker and the plan in force.
    let dump = cluster.master.telemetry_json();
    let workers = dump.get("registry").get("workers").as_arr().unwrap();
    assert_eq!(workers.len(), n);
    assert!(dump.get("adaptive").as_bool().unwrap());
    assert!(!dump.get("plan").as_arr().unwrap().is_empty());
    cluster.shutdown().unwrap();
}
