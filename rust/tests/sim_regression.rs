//! Deterministic sim regressions (satellite of PR 1): fixed-seed runs of
//! all three `sim::Scenario` variants must produce bitwise-identical
//! latency traces across repeated runs, and MDS must not lose to uncoded
//! under failures — for both the per-request simulator and the pipelined
//! serving simulator. No `artifacts/` required.

use cocoi::latency::SystemProfile;
use cocoi::model::zoo;
use cocoi::sim::{simulate_model, simulate_serving, MethodSim, Scenario};
use cocoi::util::Rng;

const N: usize = 10;
const TRIALS: usize = 8;

fn scenarios() -> [Scenario; 3] {
    [
        Scenario::Straggling { lambda_tr: 0.5 },
        Scenario::Failures { n_f: 2 },
        Scenario::FailuresPlusStraggler { n_f: 1, slowdown: 1.68 },
    ]
}

fn trace(method: MethodSim, scenario: Scenario, seed: u64) -> Vec<f64> {
    let model = zoo::model("vgg16").unwrap();
    let p = SystemProfile::paper_default();
    let mut rng = Rng::new(seed);
    simulate_model(&model, &p, N, method, scenario, TRIALS, &mut rng)
        .unwrap()
        .trials
}

fn serving_trace(method: MethodSim, scenario: Scenario, pipelined: bool, seed: u64) -> Vec<f64> {
    let model = zoo::model("vgg16").unwrap();
    let p = SystemProfile::paper_default();
    let mut rng = Rng::new(seed);
    simulate_serving(&model, &p, N, method, scenario, 4, pipelined, TRIALS, &mut rng)
        .unwrap()
        .trials
}

fn assert_bitwise_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: trace lengths differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: trial {i} differs ({x} vs {y})"
        );
    }
}

/// Same seed ⇒ bitwise-identical latency trace, for every scenario.
#[test]
fn fixed_seed_traces_are_reproducible() {
    for scenario in scenarios() {
        for method in [MethodSim::CocoiKCirc, MethodSim::Uncoded] {
            let a = trace(method, scenario, 42);
            let b = trace(method, scenario, 42);
            assert_bitwise_equal(&a, &b, &format!("{method:?}/{scenario:?}"));
            assert!(a.iter().all(|t| t.is_finite() && *t > 0.0));
        }
    }
}

/// Different seeds must actually change the draws (guards against a
/// simulator that ignores its RNG and trivially passes the test above).
#[test]
fn different_seeds_differ() {
    let a = trace(MethodSim::CocoiKCirc, Scenario::Failures { n_f: 2 }, 1);
    let b = trace(MethodSim::CocoiKCirc, Scenario::Failures { n_f: 2 }, 2);
    assert_ne!(a, b);
}

/// Under worker failures, coded MDS must not be slower than uncoded:
/// uncoded re-executes every lost piece, MDS absorbs up to n − k.
#[test]
fn mds_not_slower_than_uncoded_under_failures() {
    for n_f in [1usize, 2] {
        let scenario = Scenario::Failures { n_f };
        let mds = trace(MethodSim::CocoiKCirc, scenario, 7);
        let unc = trace(MethodSim::Uncoded, scenario, 7);
        let mds_mean = mds.iter().sum::<f64>() / mds.len() as f64;
        let unc_mean = unc.iter().sum::<f64>() / unc.len() as f64;
        assert!(
            mds_mean <= unc_mean,
            "n_f={n_f}: mds {mds_mean:.2}s > uncoded {unc_mean:.2}s"
        );
    }
}

/// The same two regressions hold with the pipelined serving engine.
#[test]
fn pipelined_serving_traces_are_reproducible() {
    for scenario in scenarios() {
        let a = serving_trace(MethodSim::CocoiKCirc, scenario, true, 42);
        let b = serving_trace(MethodSim::CocoiKCirc, scenario, true, 42);
        assert_bitwise_equal(&a, &b, &format!("serving/{scenario:?}"));
        assert!(a.iter().all(|t| t.is_finite() && *t > 0.0));
    }
}

#[test]
fn pipelined_serving_mds_not_slower_than_uncoded_under_failures() {
    let scenario = Scenario::Failures { n_f: 2 };
    let mds = serving_trace(MethodSim::CocoiKCirc, scenario, true, 9);
    let unc = serving_trace(MethodSim::Uncoded, scenario, true, 9);
    let mds_mean = mds.iter().sum::<f64>() / mds.len() as f64;
    let unc_mean = unc.iter().sum::<f64>() / unc.len() as f64;
    assert!(
        mds_mean <= unc_mean,
        "pipelined serving: mds {mds_mean:.2}s > uncoded {unc_mean:.2}s"
    );
}

/// Pipelining helps (or at worst ties) the barrier for a multi-request
/// load, per-trial, at identical phase draws — and never changes the
/// per-request phase statistics themselves.
#[test]
fn pipelined_serving_beats_barrier_per_trial() {
    for scenario in scenarios() {
        let pipe = serving_trace(MethodSim::CocoiKCirc, scenario, true, 21);
        let barrier = serving_trace(MethodSim::CocoiKCirc, scenario, false, 21);
        for (p, b) in pipe.iter().zip(&barrier) {
            assert!(
                *p <= b * (1.0 + 1e-9),
                "{scenario:?}: pipelined {p:.3}s > barrier {b:.3}s"
            );
        }
        let pm = pipe.iter().sum::<f64>() / pipe.len() as f64;
        let bm = barrier.iter().sum::<f64>() / barrier.len() as f64;
        assert!(pm < bm, "{scenario:?}: no pipelining gain ({pm:.3} vs {bm:.3})");
    }
}
