"""Model zoo (python side): parses the same `config/models.json` the rust
coordinator embeds, provides shape inference and a pure-jnp forward pass
used as the L2 oracle."""

import json
import os

import jax.numpy as jnp
from jax import lax

_HERE = os.path.dirname(os.path.abspath(__file__))
MODELS_JSON = os.path.normpath(os.path.join(_HERE, "..", "..", "config", "models.json"))


def load_zoo(path: str = MODELS_JSON):
    with open(path) as f:
        return json.load(f)["models"]


def model(name: str, path: str = MODELS_JSON):
    for m in load_zoo(path):
        if m["name"] == name:
            return m
    raise KeyError(f"unknown model '{name}'")


def conv_out(dim: int, k: int, s: int, p: int) -> int:
    return (dim + 2 * p - k) // s + 1


def infer_shapes(m):
    """Mirror of rust `ModelSpec::infer_shapes` — (C, H, W) per node id."""
    shapes = {"input": tuple(m["input"])}
    for l in m["layers"]:
        c0, h0, w0 = shapes[l["in"][0]]
        op = l["op"]
        if op == "conv":
            assert c0 == l["c_in"], f"{l['id']}: c_in mismatch"
            out = (
                l["c_out"],
                conv_out(h0, l["k"], l["s"], l["p"]),
                conv_out(w0, l["k"], l["s"], l["p"]),
            )
        elif op == "maxpool":
            p = l.get("p", 0)
            out = (c0, conv_out(h0, l["k"], l["s"], p), conv_out(w0, l["k"], l["s"], p))
        elif op == "gap":
            out = (c0, 1, 1)
        elif op == "linear":
            assert c0 * h0 * w0 == l["c_in"], f"{l['id']}: flatten mismatch"
            out = (l["c_out"], 1, 1)
        elif op == "add":
            assert shapes[l["in"][1]] == (c0, h0, w0)
            out = (c0, h0, w0)
        elif op == "relu":
            out = (c0, h0, w0)
        else:
            raise ValueError(f"unknown op {op}")
        shapes[l["id"]] = out
    return shapes


def forward(m, params, x):
    """Pure-jnp forward pass. `params[layer_id] = (w, b)`; `x (C, H, W)`."""
    values = {"input": x}
    for l in m["layers"]:
        a = values[l["in"][0]]
        op = l["op"]
        if op == "conv":
            w, b = params[l["id"]]
            y = lax.conv_general_dilated(
                a[None],
                w,
                window_strides=(l["s"], l["s"]),
                padding=[(l["p"], l["p"])] * 2,
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )[0]
            y = y + b[:, None, None]
            if l.get("relu"):
                y = jnp.maximum(y, 0.0)
        elif op == "maxpool":
            p = l.get("p", 0)
            y = lax.reduce_window(
                a,
                -jnp.inf,
                lax.max,
                (1, l["k"], l["k"]),
                (1, l["s"], l["s"]),
                [(0, 0), (p, p), (p, p)],
            )
        elif op == "gap":
            y = jnp.mean(a, axis=(1, 2), keepdims=True)
        elif op == "linear":
            w, b = params[l["id"]]
            y = (w @ a.reshape(-1) + b).reshape(-1, 1, 1)
            if l.get("relu"):
                y = jnp.maximum(y, 0.0)
        elif op == "add":
            y = a + values[l["in"][1]]
            if l.get("relu"):
                y = jnp.maximum(y, 0.0)
        elif op == "relu":
            y = jnp.maximum(a, 0.0)
        else:
            raise ValueError(f"unknown op {op}")
        values[l["id"]] = y
    return values[m["layers"][-1]["id"]]


def random_params(m, seed: int = 0):
    """He-style deterministic init (numpy-side; tests only — the rust
    WeightStore is the runtime source of parameters)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    params = {}
    for l in m["layers"]:
        if l["op"] == "conv":
            fan_in = l["c_in"] * l["k"] * l["k"]
            bound = (3.0 / fan_in) ** 0.5
            w = rng.uniform(-bound, bound, (l["c_out"], l["c_in"], l["k"], l["k"]))
            b = rng.uniform(-0.05, 0.05, l["c_out"])
            params[l["id"]] = (jnp.float32(w), jnp.float32(b))
        elif l["op"] == "linear":
            bound = (3.0 / l["c_in"]) ** 0.5
            w = rng.uniform(-bound, bound, (l["c_out"], l["c_in"]))
            b = rng.uniform(-0.05, 0.05, l["c_out"])
            params[l["id"]] = (jnp.float32(w), jnp.float32(b))
    return params
