"""L2: the jax compute graphs that get AOT-lowered for the rust runtime.

Three graph families, all calling the L1 Pallas kernels:

* `conv_subtask` — the worker-side unit of CoCoI: a *pure* valid conv of
  an (already padded, already encoded) input partition. Weights arrive as
  runtime arguments so one artifact serves every weight set.
* `gemm_tile` — fixed-shape GEMM tile for the shape-polymorphic provider.
* `encode` — the master's MDS encode offload.

The full-model forward in `models_zoo.forward` is the oracle used by
pytest to validate the distributed decomposition end-to-end in python
before anything touches rust.
"""

import jax
import jax.numpy as jnp

from .kernels.coding import encode_pallas
from .kernels.conv2d import conv2d_pallas
from .kernels.gemm import gemm_pallas


def conv_subtask(x, w, stride: int):
    """Worker subtask: valid conv, no bias, no activation (linearity is
    what makes MDS decode exact — see paper §II-B)."""
    return (conv2d_pallas(x, w, stride=stride),)


def gemm_tile(a, b):
    """One (M, K) @ (K, N) tile."""
    return (gemm_pallas(a, b),)


def encode(g, x):
    """MDS encode `G @ X`."""
    return (encode_pallas(g, x),)


def lower_conv_subtask(c_in, h_i, w_i_p, c_out, k, stride):
    """jit+lower a conv subtask for one concrete partition shape."""
    x = jax.ShapeDtypeStruct((c_in, h_i, w_i_p), jnp.float32)
    w = jax.ShapeDtypeStruct((c_out, c_in, k, k), jnp.float32)
    return jax.jit(lambda x, w: conv_subtask(x, w, stride)).lower(x, w)


def lower_gemm_tile(m, kk, n):
    a = jax.ShapeDtypeStruct((m, kk), jnp.float32)
    b = jax.ShapeDtypeStruct((kk, n), jnp.float32)
    return jax.jit(gemm_tile).lower(a, b)


def lower_encode(n, k, mlen):
    g = jax.ShapeDtypeStruct((n, k), jnp.float32)
    x = jax.ShapeDtypeStruct((k, mlen), jnp.float32)
    return jax.jit(encode).lower(g, x)
