"""AOT bridge: lower every artifact in the manifest to HLO **text**.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (what `make artifacts` runs):
    cd python && python -m compile.aot --out ../artifacts

Python runs ONLY here, at build time. The rust binary is self-contained
once `artifacts/` exists.
"""

import argparse
import json
import os
import sys
import time

from jax._src.lib import xla_client as xc

from . import model as l2
from . import models_zoo

# Fused conv-subtask artifacts are generated for the models actually
# executed end-to-end on this testbed, for every split 1..=N_WORKERS.
DEFAULT_MODELS = ["tinyvgg", "tinyresnet"]
DEFAULT_N_WORKERS = 6
# Shape-polymorphic GEMM tiles for the fallback provider.
GEMM_TILES = [(128, 128, 128), (256, 256, 256)]
# One encode-offload artifact (n, k, m) as a demonstrator.
ENCODE_SHAPES = [(6, 3, 8192)]


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def conv_subtask_shapes(m, n_workers):
    """All distinct (layer, k_split) subtask shapes of a model — mirrors
    rust conv::split (eqs. 1-2 with floored piece widths)."""
    shapes = models_zoo.infer_shapes(m)
    out = {}
    for l in m["layers"]:
        if l["op"] != "conv":
            continue
        _, h_in, w_in = shapes[l["in"][0]]
        h_i, w_i = h_in + 2 * l["p"], w_in + 2 * l["p"]
        h_o = (h_i - l["k"]) // l["s"] + 1
        w_o = (w_i - l["k"]) // l["s"] + 1
        for k_split in range(1, n_workers + 1):
            if k_split > w_o:
                break
            w_o_p = w_o // k_split
            w_i_p = l["k"] + (w_o_p - 1) * l["s"]
            key = (l["c_in"], l["c_out"], l["k"], l["s"], h_i, w_i_p)
            out.setdefault(
                key,
                {
                    "kind": "conv_subtask",
                    "c_in": l["c_in"],
                    "c_out": l["c_out"],
                    "k_w": l["k"],
                    "s_w": l["s"],
                    "h_i": h_i,
                    "w_i_p": w_i_p,
                    "h_o": h_o,
                    "w_o_p": w_o_p,
                    "uses": [],
                },
            )["uses"].append(f"{m['name']}/{l['id']}/k{k_split}")
    return out


def emit(out_dir: str, models, n_workers: int, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "n_workers": n_workers, "artifacts": []}

    def write(name: str, lowered, meta: dict):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta, name=name, file=f"{name}.hlo.txt")
        manifest["artifacts"].append(meta)
        if verbose:
            print(
                f"  {name}: {len(text) / 1024:.0f} KiB in {time.time() - t0:.1f}s",
                file=sys.stderr,
            )

    # 1. Fused conv subtasks.
    for model_name in models:
        m = models_zoo.model(model_name)
        shapes = conv_subtask_shapes(m, n_workers)
        if verbose:
            print(
                f"{model_name}: {len(shapes)} distinct conv-subtask shapes",
                file=sys.stderr,
            )
        for meta in shapes.values():
            name = (
                f"conv_{meta['c_in']}x{meta['c_out']}"
                f"_k{meta['k_w']}s{meta['s_w']}"
                f"_h{meta['h_i']}_w{meta['w_i_p']}"
            )
            if any(a["name"] == name for a in manifest["artifacts"]):
                continue  # shape shared across models
            lowered = l2.lower_conv_subtask(
                meta["c_in"], meta["h_i"], meta["w_i_p"],
                meta["c_out"], meta["k_w"], meta["s_w"],
            )
            write(name, lowered, meta)

    # 2. GEMM tiles.
    for (m_, k_, n_) in GEMM_TILES:
        write(
            f"gemm_{m_}x{k_}x{n_}",
            l2.lower_gemm_tile(m_, k_, n_),
            {"kind": "gemm_tile", "m": m_, "k": k_, "n": n_},
        )

    # 3. Encode offload demo.
    for (n_, k_, mlen) in ENCODE_SHAPES:
        write(
            f"encode_n{n_}k{k_}m{mlen}",
            l2.lower_encode(n_, k_, mlen),
            {"kind": "encode", "n": n_, "k": k_, "m_len": mlen},
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--n-workers", type=int, default=DEFAULT_N_WORKERS)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    manifest = emit(
        args.out,
        [m for m in args.models.split(",") if m],
        args.n_workers,
        verbose=not args.quiet,
    )
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
