"""L1 Pallas kernel: MDS encode (paper eq. 3) as a blocked matrix product.

`G (n, k) @ X (k, m)` where the rows of X are flattened input partitions.
`n, k <= ~20` while `m` is huge (C_I*H_I*W_I^p), so the kernel blocks the
*m* dimension only and keeps the whole generator in registers/VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _encode_kernel(g_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(
        g_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm",))
def encode_pallas(g, x, bm: int = 2048):
    """Encode k partitions of length m into n: `(n,k) @ (k,m) -> (n,m)`,
    blocked along m. m must be a multiple of bm (callers pad)."""
    n, k = g.shape
    k2, m = x.shape
    assert k == k2, "generator/partition mismatch"
    bm = min(bm, m)
    assert m % bm == 0, "pad m to a block multiple"
    return pl.pallas_call(
        _encode_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((k, bm), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, bm), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(g, x)


def vandermonde(n: int, k: int):
    """The MdsCode generator used by rust (coding::mds): Vandermonde rows
    `[g^(k-1), ..., g^0]` over nodes evenly spaced in [-1, 1]. Kept in sync
    with rust by the cross-language test in tests/test_coding_kernel.py."""
    if n == 1:
        nodes = jnp.array([1.0], dtype=jnp.float32)
    else:
        nodes = jnp.linspace(-1.0, 1.0, n, dtype=jnp.float32)
    powers = jnp.arange(k - 1, -1, -1, dtype=jnp.float32)
    return nodes[:, None] ** powers[None, :]


def decode_ref(g_sub, y):
    """Reference decode (eq. 4): solve G_S^{-1} @ Y without forming the
    inverse. Used by pytest to close the encode→compute→decode loop."""
    return jnp.linalg.solve(g_sub.astype(jnp.float64), y.astype(jnp.float64)).astype(
        jnp.float32
    )


__all__ = ["encode_pallas", "vandermonde", "decode_ref", "ref"]
