"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

These are the ground truth the pytest suite checks the kernels against —
deliberately written with stock jax ops (lax.conv / jnp.dot) and zero
Pallas machinery.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, stride: int):
    """Valid (unpadded) 2D convolution.

    x: (C_I, H_I, W_I) already-padded input feature map.
    w: (C_O, C_I, K, K) kernel.
    returns: (C_O, H_O, W_O).
    """
    out = lax.conv_general_dilated(
        x[None],  # NCHW with N=1
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def gemm_ref(a, b):
    """Plain matmul: (M, K) @ (K, N) in f32."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def encode_ref(g, x):
    """MDS encode: generator (n, k) applied to k flattened partitions
    (k, m) -> (n, m)."""
    return jnp.dot(g, x, preferred_element_type=jnp.float32)
