"""L1 Pallas kernel: the conv-subtask hot spot.

The kernel computes a *valid* 2D convolution of an already-padded input
partition — exactly the linear map CoCoI distributes to workers (bias and
activation stay on the master, after MDS decode; see rust/src/coding).

Structure (the TPU-shaped design, DESIGN.md §Hardware-Adaptation):

* grid walks the **output width** in blocks — the same dimension CoCoI
  splits across workers, so one subtask's HBM↔VMEM schedule mirrors the
  system-level split;
* the K×K taps are a static python loop; each tap contributes an
  `einsum('oc,chw->ohw')` — a (C_O × C_I) · (C_I × H_O·W_b) contraction
  that maps onto the MXU systolic array;
* the input stays unblocked (the overlapping receptive fields of adjacent
  width blocks make BlockSpec-level blocking of the input unsound) and is
  sliced dynamically per program instance.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both jax and the
rust runtime execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_block_kernel(x_ref, w_ref, o_ref, *, stride: int, k: int, w_block: int):
    """One program instance: compute `w_block` output columns."""
    i = pl.program_id(0)
    c_o, h_o, _ = o_ref.shape
    c_i = x_ref.shape[0]
    # Input span covering this output block (eq. 1 of the paper at the
    # kernel scale): start = block_start * stride, width K + (w_block-1)*S.
    x_start = i * w_block * stride
    in_span = k + (w_block - 1) * stride
    x_blk = x_ref[:, :, pl.ds(x_start, in_span)]  # (C_I, H_I, in_span)

    acc = jnp.zeros((c_o, h_o, w_block), dtype=jnp.float32)
    for ky in range(k):
        for kx in range(k):
            # Strided tap window: (C_I, H_O, w_block).
            tap = jax.lax.slice(
                x_blk,
                (0, ky, kx),
                (c_i, ky + (h_o - 1) * stride + 1, kx + (w_block - 1) * stride + 1),
                (1, stride, stride),
            )
            # (C_O, C_I) x (C_I, H_O*w_block) on the MXU.
            acc = acc + jnp.einsum(
                "oc,chw->ohw",
                w_ref[:, :, ky, kx],
                tap,
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = acc


def _pick_w_block(w_o: int) -> int:
    """Largest divisor of W_O not exceeding 16 — keeps the VMEM slab for
    (input span + output block) small while amortizing the tap loop."""
    for cand in range(min(16, w_o), 0, -1):
        if w_o % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("stride", "w_block"))
def conv2d_pallas(x, w, stride: int = 1, w_block: int | None = None):
    """Valid conv of padded input `x (C_I, H_I, W_I)` with `w (C_O, C_I,
    K, K)`, gridded over output-width blocks."""
    c_i, h_i, w_i = x.shape
    c_o, c_i2, k, k2 = w.shape
    assert c_i == c_i2 and k == k2, "weight shape mismatch"
    h_o = (h_i - k) // stride + 1
    w_o = (w_i - k) // stride + 1
    if w_block is None:
        w_block = _pick_w_block(w_o)
    assert w_o % w_block == 0, f"w_block {w_block} must divide W_O {w_o}"

    kernel = functools.partial(
        _conv_block_kernel, stride=stride, k=k, w_block=w_block
    )
    return pl.pallas_call(
        kernel,
        grid=(w_o // w_block,),
        in_specs=[
            # Full input per program: overlapping receptive fields.
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((c_o, h_o, w_block), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((c_o, h_o, w_o), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w)


def vmem_estimate_bytes(c_i, h_i, c_o, h_o, k, stride, w_block) -> int:
    """Structural VMEM footprint of one program instance (perf model):
    input span + weights + output block, f32."""
    in_span = k + (w_block - 1) * stride
    return 4 * (c_i * h_i * in_span + c_o * c_i * k * k + c_o * h_o * w_block)


def mxu_utilization_estimate(c_i, c_o) -> float:
    """Fraction of a 128×128 MXU tile the per-tap contraction fills."""
    return min(c_i, 128) * min(c_o, 128) / (128.0 * 128.0)
