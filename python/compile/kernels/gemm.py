"""L1 Pallas kernel: MXU-tiled GEMM.

Two uses:
* the fixed-shape **tile artifact** behind the rust shape-polymorphic
  provider (rust does im2col + tiling, this kernel does each
  `(BM, BK) @ (BK, BN)` tile);
* the MDS **encode** matrix product `G (n,k) @ X (k,m)` when the master
  offloads coding to the runtime.

Classic 3-D grid (M/BM, N/BN, K/BK) with an accumulator carried in the
output block across the K-steps (revisiting: K is the innermost grid dim).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_pallas(a, b, bm: int = 128, bn: int = 128, bk: int = 128):
    """`a (M, K) @ b (K, N)` with (bm, bn, bk) MXU tiles. Dimensions must
    be tile multiples (the rust caller pads)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, "inner dims differ"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, "pad to tile multiples"
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_estimate_bytes(bm, bn, bk) -> int:
    """Structural VMEM per program: A tile + B tile + accumulator, f32,
    double-buffered inputs."""
    return 4 * (2 * bm * bk + 2 * bk * bn + bm * bn)
