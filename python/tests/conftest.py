"""Test-collection guard: make `compile` importable without an installed
package, and skip the jax/hypothesis suites gracefully when those heavy
deps are absent (CI runners and the rust-only dev container)."""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.normpath(os.path.join(os.path.dirname(__file__), "..")))

collect_ignore = []

_HAVE_JAX = importlib.util.find_spec("jax") is not None
_HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

if not _HAVE_JAX:
    # All three L1/L2 suites import jax at module level.
    collect_ignore += ["test_aot.py", "test_kernels.py", "test_model.py"]
elif not _HAVE_HYPOTHESIS:
    collect_ignore += ["test_kernels.py"]
