"""Dependency-free sanity suite: runs on any interpreter, so `pytest
python/tests` never collects zero tests even without jax/hypothesis."""

import ast
import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[2]


def test_python_sources_parse():
    """Every python source in the repo must be syntactically valid."""
    checked = 0
    for path in sorted(REPO.rglob("*.py")):
        ast.parse(path.read_text(), filename=str(path))
        checked += 1
    assert checked >= 10


def test_models_json_schema():
    """The model-zoo config shared with the rust coordinator must parse
    and keep the fields both sides rely on."""
    cfg = REPO / "config" / "models.json"
    models = json.loads(cfg.read_text())["models"]
    assert {m["name"] for m in models} >= {"vgg16", "resnet18", "tinyvgg", "tinyresnet"}
    for m in models:
        assert len(m["input"]) == 3 and m["layers"], m["name"]


def test_kernel_modules_define_entry_points():
    """Static check (no imports): the Pallas kernel modules keep their
    public entry points that test_kernels/test_model call."""
    wanted = {
        "conv2d.py": "conv2d_pallas",
        "gemm.py": "gemm_pallas",
        "coding.py": "encode_pallas",
    }
    kdir = REPO / "python" / "compile" / "kernels"
    for fname, func in wanted.items():
        tree = ast.parse((kdir / fname).read_text())
        names = {n.name for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)}
        assert func in names, f"{fname} lost {func}"
