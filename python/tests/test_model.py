"""L2 correctness: zoo shape algebra, the full-model jnp oracle, and the
python-side distributed decomposition (split -> encode -> conv -> decode
-> concat == direct layer output)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import models_zoo as zoo
from compile.kernels.coding import decode_ref, vandermonde
from compile.kernels.conv2d import conv2d_pallas
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand(*shape):
    return jnp.float32(RNG.standard_normal(shape))


def test_zoo_loads_all_models():
    names = [m["name"] for m in zoo.load_zoo()]
    assert names == ["vgg16", "resnet18", "tinyvgg", "tinyresnet"]


@pytest.mark.parametrize("name,convs", [("vgg16", 13), ("resnet18", 20),
                                        ("tinyvgg", 6), ("tinyresnet", 9)])
def test_conv_counts_match_paper(name, convs):
    m = zoo.model(name)
    assert sum(1 for l in m["layers"] if l["op"] == "conv") == convs


def test_shape_inference_known_values():
    shapes = zoo.infer_shapes(zoo.model("vgg16"))
    assert shapes["conv1"] == (64, 224, 224)
    assert shapes["conv13"] == (512, 14, 14)
    shapes = zoo.infer_shapes(zoo.model("resnet18"))
    assert shapes["conv1"] == (64, 112, 112)
    assert shapes["fc"] == (1000, 1, 1)


@pytest.mark.parametrize("name", ["tinyvgg", "tinyresnet"])
def test_forward_runs_and_matches_shapes(name):
    m = zoo.model(name)
    params = zoo.random_params(m, seed=3)
    x = rand(*m["input"])
    out = zoo.forward(m, params, x)
    expect = zoo.infer_shapes(m)[m["layers"][-1]["id"]]
    assert out.shape == expect
    assert bool(jnp.all(jnp.isfinite(out)))


def test_distributed_layer_equals_direct():
    """One full CoCoI round on a real tinyvgg layer, in python: width-split
    (eqs. 1-2), MDS encode, per-worker pallas conv, decode from a k-subset,
    concat — must equal the direct conv of the whole input."""
    m = zoo.model("tinyvgg")
    conv = next(l for l in m["layers"] if l["id"] == "conv3")  # 32->64
    n, k_split = 5, 3
    c_i, c_o, kk, s, p = conv["c_in"], conv["c_out"], conv["k"], conv["s"], conv["p"]
    h_in, w_in = 28, 28
    x = rand(c_i, h_in, w_in)
    w = rand(c_o, c_i, kk, kk)
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p)))
    full = ref.conv2d_ref(xp, w, s)
    w_o = full.shape[2]
    w_o_p = w_o // k_split
    w_i_p = kk + (w_o_p - 1) * s

    # Split (input ranges per eq. 2).
    pieces = []
    for i in range(k_split):
        a_o = i * w_o_p
        a_i = a_o * s
        pieces.append(xp[:, :, a_i:a_i + w_i_p].reshape(-1))
    sources = jnp.stack(pieces)

    g = vandermonde(n, k_split)
    encoded = ref.encode_ref(g, sources)
    outs = jnp.stack([
        conv2d_pallas(encoded[i].reshape(c_i, xp.shape[1], w_i_p), w, stride=s).reshape(-1)
        for i in range(n)
    ])
    subset = jnp.array([1, 2, 4])
    decoded = decode_ref(g[subset], outs[subset])
    got = jnp.concatenate(
        [decoded[i].reshape(c_o, full.shape[1], w_o_p) for i in range(k_split)],
        axis=2,
    )
    # Remainder columns (w_o % k_split) are master-local; compare the coded part.
    np.testing.assert_allclose(
        got, full[:, :, : k_split * w_o_p], rtol=2e-3, atol=2e-3
    )
