"""AOT path: HLO-text emission, manifest schema, and numeric equivalence
of a lowered artifact executed through jax itself (the rust runtime
round-trip is covered by rust integration tests)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model as l2, models_zoo as zoo

RNG = np.random.default_rng(11)


def test_hlo_text_emission_smoke():
    lowered = l2.lower_conv_subtask(4, 10, 7, 8, 3, 1)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[8,4,3,3]" in text  # weight parameter shape present
    # No Mosaic custom-call may leak into a CPU artifact.
    assert "mosaic" not in text.lower()


def test_conv_subtask_shapes_cover_all_convs():
    m = zoo.model("tinyvgg")
    shapes = aot.conv_subtask_shapes(m, 6)
    conv_ids = {l["id"] for l in m["layers"] if l["op"] == "conv"}
    used = {u.split("/")[1] for meta in shapes.values() for u in meta["uses"]}
    assert used == conv_ids
    # Every entry satisfies eq. 1.
    for meta in shapes.values():
        assert meta["w_i_p"] == meta["k_w"] + (meta["w_o_p"] - 1) * meta["s_w"]


def test_emit_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.emit(out, ["tinyvgg"], n_workers=2, verbose=False)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    kinds = {a["kind"] for a in manifest["artifacts"]}
    assert kinds == {"conv_subtask", "gemm_tile", "encode"}
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["name"]
        with open(path) as f:
            assert f.read(9) == "HloModule"


def test_lowered_subtask_equals_jit_execution():
    """Executing the lowered computation (compiled from the same lowering
    we serialize) must equal calling the kernel directly."""
    c_in, h_i, w_i_p, c_out, k, s = 3, 12, 9, 5, 3, 1
    lowered = l2.lower_conv_subtask(c_in, h_i, w_i_p, c_out, k, s)
    compiled = lowered.compile()
    x = jnp.float32(RNG.standard_normal((c_in, h_i, w_i_p)))
    w = jnp.float32(RNG.standard_normal((c_out, c_in, k, k)))
    (via_artifact,) = compiled(x, w)
    (direct,) = l2.conv_subtask(x, w, s)
    np.testing.assert_allclose(via_artifact, direct, rtol=1e-5, atol=1e-5)
