"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/strides; this is the CORE correctness signal for
the compute that ends up inside every AOT artifact.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv2d import (
    conv2d_pallas,
    mxu_utilization_estimate,
    vmem_estimate_bytes,
    _pick_w_block,
)
from compile.kernels.gemm import gemm_pallas
from compile.kernels.coding import decode_ref, encode_pallas, vandermonde
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def rand(*shape):
    return jnp.float32(RNG.standard_normal(shape))


# ---------------------------------------------------------------- conv2d

@settings(max_examples=25, deadline=None)
@given(
    c_i=st.integers(1, 8),
    c_o=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    s=st.integers(1, 2),
    h_extra=st.integers(0, 6),
    w_extra=st.integers(0, 12),
)
def test_conv2d_matches_ref(c_i, c_o, k, s, h_extra, w_extra):
    h_i = k + h_extra
    w_i = k + w_extra
    x = rand(c_i, h_i, w_i)
    w = rand(c_o, c_i, k, k)
    got = conv2d_pallas(x, w, stride=s)
    want = ref.conv2d_ref(x, w, s)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_explicit_w_block():
    x = rand(4, 10, 34)
    w = rand(6, 4, 3, 3)
    want = ref.conv2d_ref(x, w, 1)  # W_O = 32
    for w_block in [1, 2, 4, 8, 16, 32]:
        got = conv2d_pallas(x, w, stride=1, w_block=w_block)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_subtask_shapes_from_paper_split():
    # A k-way split piece: W_I^p = K + (W_O^p - 1) S (paper eq. 1).
    k_kernel, stride = 3, 1
    w_o_p = 14
    w_i_p = k_kernel + (w_o_p - 1) * stride
    x = rand(32, 58, w_i_p)
    w = rand(32, 32, k_kernel, k_kernel)
    got = conv2d_pallas(x, w, stride=stride)
    assert got.shape == (32, 56, w_o_p)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, w, stride), rtol=1e-4, atol=1e-4)


def test_pick_w_block_divides():
    for w_o in range(1, 200):
        b = _pick_w_block(w_o)
        assert w_o % b == 0 and 1 <= b <= 16


def test_structural_perf_estimates():
    # Estimates are used by DESIGN.md §Perf — sanity-bound them.
    vmem = vmem_estimate_bytes(c_i=128, h_i=58, c_o=128, h_o=56, k=3, stride=1, w_block=16)
    assert vmem < 16 * 2**20, "one program instance must fit VMEM"
    assert 0.0 < mxu_utilization_estimate(128, 128) <= 1.0
    assert mxu_utilization_estimate(3, 32) < 0.01  # stem conv underfills MXU


# ------------------------------------------------------------------ gemm

@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 7, 64, 128]),
    k=st.sampled_from([1, 32, 128]),
    n=st.sampled_from([1, 5, 128]),
)
def test_gemm_matches_ref_unblocked(m, k, n):
    # When dims < block, gemm_pallas clamps blocks to the dims.
    a, b = rand(m, k), rand(k, n)
    np.testing.assert_allclose(
        gemm_pallas(a, b), ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4
    )


def test_gemm_tiled_multi_step():
    # Forces a real 3-D grid with K-accumulation: 256/128 = 2 steps per dim.
    a, b = rand(256, 256), rand(256, 256)
    np.testing.assert_allclose(
        gemm_pallas(a, b, bm=128, bn=128, bk=128),
        ref.gemm_ref(a, b),
        rtol=1e-3,
        atol=1e-3,
    )


# ---------------------------------------------------------------- coding

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 10), data=st.data())
def test_encode_matches_ref(n, data):
    k = data.draw(st.integers(1, n))
    g = vandermonde(n, k)
    x = rand(k, 2048)
    np.testing.assert_allclose(
        encode_pallas(g, x, bm=1024), ref.encode_ref(g, x), rtol=1e-4, atol=1e-4
    )


def test_encode_compute_decode_closes():
    """The CoCoI linearity loop in pure python: encode inputs, convolve
    each encoded partition, decode any-k outputs, compare to convolving
    the sources directly."""
    n, k = 5, 3
    c_i, h_i, w_i_p = 4, 9, 7
    stride, kk = 1, 3
    g = vandermonde(n, k)
    sources = rand(k, c_i * h_i * w_i_p)
    w = rand(6, c_i, kk, kk)

    encoded = encode_pallas(g, sources, bm=sources.shape[1])

    conv = lambda flat: ref.conv2d_ref(
        flat.reshape(c_i, h_i, w_i_p), w, stride
    ).reshape(-1)
    encoded_outputs = jnp.stack([conv(encoded[i]) for i in range(n)])
    subset = [0, 2, 4]
    decoded = decode_ref(g[jnp.array(subset)], encoded_outputs[jnp.array(subset)])
    direct = jnp.stack([conv(sources[i]) for i in range(k)])
    np.testing.assert_allclose(decoded, direct, rtol=1e-3, atol=1e-3)


def test_vandermonde_matches_rust_layout():
    # rust coding::mds: nodes evenly spaced in [-1, 1], rows [g^(k-1)..g^0].
    g = np.asarray(vandermonde(3, 2))
    np.testing.assert_allclose(g, [[-1.0, 1.0], [0.0, 1.0], [1.0, 1.0]], atol=1e-7)
    g1 = np.asarray(vandermonde(1, 1))
    np.testing.assert_allclose(g1, [[1.0]])
