//! End-to-end serving driver (DESIGN.md §6): load TinyVGG through the
//! AOT PJRT artifacts (pure-rust fallback if `artifacts/` is absent),
//! start 6 in-process workers with mild injected straggling, serve a
//! stream of image requests through the coded pipeline, and report
//! latency percentiles + throughput — cross-checking every response
//! against local inference.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve
//! COCOI_SERVE_REQUESTS=50 cargo run --release --example serve
//! ```

use std::sync::Arc;

use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, ScenarioFaults,
    SchemeKind, ServeError, ServerConfig, SubmitError,
};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::{ConvProvider, FallbackProvider, Manifest, PjrtProvider, PjrtService};
use cocoi::util::stats::Summary;
use cocoi::util::Rng;

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();
    let n = 6;
    let requests: usize = std::env::var("COCOI_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);

    // Provider: PJRT artifacts when available (the production path).
    let dir = cocoi::runtime::artifacts::default_dir();
    let _service; // keep the PJRT service alive for the whole run
    let provider: Arc<dyn ConvProvider> = if dir.join("manifest.json").exists() {
        let service = PjrtService::spawn()?;
        let manifest = Arc::new(Manifest::load(&dir)?);
        println!(
            "provider: pjrt ({} fused conv artifacts, {} gemm tiles)",
            manifest.conv.len(),
            manifest.gemm.len()
        );
        let p = Arc::new(PjrtProvider::new(service.handle(), manifest));
        _service = Some(service);
        p
    } else {
        println!("provider: pure-rust fallback (run `make artifacts` for the PJRT path)");
        _service = None;
        Arc::new(FallbackProvider::new())
    };

    // Mild real straggling on every worker.
    let faults = ScenarioFaults::straggling(n, 0.3, 0.010);
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(4), // r = 2 redundancy at n = 6
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn("tinyvgg", n, config, provider.clone(), faults)?;

    // Local reference for correctness cross-checks.
    let model = zoo::model("tinyvgg")?;
    let weights = WeightStore::generate(&model, 42)?;

    println!("serving {requests} requests on tinyvgg with n={n}, (6,4)-MDS...");
    let mut rng = Rng::new(2025);
    let mut lat = Summary::new();
    let mut coding = Summary::new();
    let t_all = std::time::Instant::now();
    let mut checked = 0;
    for req in 0..requests {
        let mut input = Tensor::zeros(3, 56, 56);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let t0 = std::time::Instant::now();
        let (out, metrics) = cluster.master.infer(&input)?;
        lat.push(t0.elapsed().as_secs_f64());
        coding.push(metrics.coding_seconds() / metrics.distributed_layer_seconds().max(1e-12));
        // Cross-check a sample of responses exactly.
        if req % 5 == 0 {
            let want = forward_local(&model, &weights, &input)?;
            let err = out.max_abs_diff(&want);
            anyhow::ensure!(err < 2e-2, "request {req}: wrong answer (err {err})");
            checked += 1;
        }
    }
    let wall = t_all.elapsed().as_secs_f64();
    cluster.shutdown()?;

    println!("\n== serving report ==");
    println!("requests      : {requests} ({checked} cross-checked exactly)");
    println!("throughput    : {:.2} req/s", requests as f64 / wall);
    println!(
        "latency       : p50 {:.0} ms   p95 {:.0} ms   p99 {:.0} ms   mean {:.0} ms",
        lat.quantile(0.5) * 1e3,
        lat.quantile(0.95) * 1e3,
        lat.quantile(0.99) * 1e3,
        lat.mean() * 1e3
    );
    println!(
        "coding share  : {:.1}% of distributed-layer time (paper Fig. 4: 2–9%)",
        coding.mean() * 100.0
    );

    // == the same load through the pipelined engine, 4 requests at a ==
    // == time multiplexed over the pool with straggler cancellation  ==
    let faults = ScenarioFaults::straggling(n, 0.3, 0.010);
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(4),
        mode: ExecMode::Pipelined,
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn("tinyvgg", n, config, provider.clone(), faults)?;
    let mut rng = Rng::new(2025); // same request stream as above
    let batch_size = 4;
    let t_all = std::time::Instant::now();
    let mut cancelled = 0usize;
    let mut served = 0usize;
    while served < requests {
        let b = batch_size.min(requests - served);
        let inputs: Vec<Tensor> = (0..b)
            .map(|_| {
                let mut input = Tensor::zeros(3, 56, 56);
                rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
                input
            })
            .collect();
        for (input, (out, metrics)) in
            inputs.iter().zip(cluster.master.infer_batch(&inputs)?)
        {
            cancelled += metrics.cancelled();
            if served % 5 == 0 {
                let want = forward_local(&model, &weights, input)?;
                let err = out.max_abs_diff(&want);
                anyhow::ensure!(err < 2e-2, "pipelined request {served}: err {err}");
            }
            served += 1;
        }
    }
    let wall_pipe = t_all.elapsed().as_secs_f64();
    cluster.shutdown()?;

    println!("\n== pipelined engine (batches of {batch_size}) ==");
    println!(
        "throughput    : {:.2} req/s ({:.2}x vs round-barrier)",
        requests as f64 / wall_pipe,
        wall / wall_pipe
    );
    println!("cancelled     : {cancelled} straggler subtasks freed early");

    // == phase 3: the streaming serving API — non-blocking submits, ==
    // == open-loop trickle, priorities + deadlines, out-of-order    ==
    // == completion, backpressure via the bounded admission queue   ==
    let faults = ScenarioFaults::straggling(n, 0.3, 0.010);
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(4),
        mode: ExecMode::Pipelined,
        ..Default::default()
    };
    let cluster = LocalCluster::spawn("tinyvgg", n, config, provider.clone(), faults)?;
    let (master, workers) = cluster.into_parts();
    let server = InferenceServer::start(
        master,
        ServerConfig {
            queue_capacity: 8,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(2025); // same request stream again
    let mut handles = Vec::new();
    let mut rejected = 0usize;
    let t_all = std::time::Instant::now();
    for i in 0..requests {
        let mut input = Tensor::zeros(3, 56, 56);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        // Every 4th request is urgent: higher priority, 30 s deadline.
        let mut req = InferenceRequest::new(input);
        if i % 4 == 0 {
            req = req
                .with_priority(1)
                .with_deadline(std::time::Duration::from_secs(30));
        }
        match server.submit(req) {
            Ok(h) => handles.push(h),
            Err(SubmitError::QueueFull) => rejected += 1, // backpressure: drop this one
            Err(e) => anyhow::bail!("submit failed: {e}"),
        }
        // Open-loop-ish trickle: requests keep arriving mid-flight.
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    // Sojourns are engine-stamped: collecting in submission order still
    // measures each (possibly out-of-order-completed) request exactly.
    let mut stream_lat = Summary::new();
    let mut shed = 0usize;
    for h in handles {
        let (res, sojourn) = h.wait_timed();
        match res {
            Ok(_) => stream_lat.push(sojourn.as_secs_f64()),
            Err(ServeError::DeadlineShed { .. }) => shed += 1,
            Err(e) => anyhow::bail!("streamed request failed: {e}"),
        }
    }
    let wall_stream = t_all.elapsed().as_secs_f64();
    let stats = server.stats();
    let master = server.shutdown()?;
    master.shutdown();
    workers.join()?;

    println!("\n== streaming serving API (submit/handle, queue cap 8) ==");
    println!(
        "served        : {} of {requests} ({} shed on deadline, {rejected} \
         refused on backpressure)",
        stream_lat.len(),
        shed
    );
    println!(
        "sojourn       : p50 {:.0} ms   p95 {:.0} ms   mean {:.0} ms",
        stream_lat.quantile(0.5) * 1e3,
        stream_lat.quantile(0.95) * 1e3,
        stream_lat.mean() * 1e3
    );
    println!(
        "throughput    : {:.2} req/s (stats: {} submitted, {} completed)",
        stream_lat.len() as f64 / wall_stream,
        stats.submitted,
        stats.completed
    );
    Ok(())
}
