//! Adaptive replanning demo: watch the telemetry loop close.
//!
//! A 10-worker VGG16 serving sim runs 32 requests; at request 8 three
//! workers silently slow down 3x. The static plan keeps paying for them;
//! the adaptive plan quarantines the stragglers, re-solves (n, k)
//! against the fitted capacities, and pulls ahead. The same loop then
//! runs the heterogeneous Monte-Carlo refinement over the fitted
//! per-worker speeds.
//!
//! Run: `cargo run --release --example adaptive`

use cocoi::latency::SystemProfile;
use cocoi::model::zoo;
use cocoi::sim::{simulate_adaptive, DriftScenario};
use cocoi::telemetry::{ReplanConfig, Replanner};
use cocoi::util::Rng;

fn main() -> anyhow::Result<()> {
    let model = zoo::model("vgg16")?;
    let p = SystemProfile::paper_default();
    let n = 10;
    let drift = DriftScenario::ComputeSlowdown { m: 3, factor: 3.0, at: 8 };

    let mut rng = Rng::new(1);
    let stat = simulate_adaptive(&model, &p, n, drift, 32, false, 4, &mut rng)?;
    let mut rng = Rng::new(1);
    let adap = simulate_adaptive(&model, &p, n, drift, 32, true, 4, &mut rng)?;

    println!("request   static(s)  adaptive(s)");
    for (i, (s, a)) in stat.latencies.iter().zip(&adap.latencies).enumerate() {
        let marker = if i == 8 { "  <- drift: workers 0-2 slow 3x" } else { "" };
        println!("{i:>7}   {s:>9.2}  {a:>11.2}{marker}");
    }
    println!(
        "\npost-drift means (requests 16..): static {:.2}s, adaptive {:.2}s ({:.1}% faster)",
        stat.mean_from(16),
        adap.mean_from(16),
        100.0 * (1.0 - adap.mean_from(16) / stat.mean_from(16)),
    );
    println!("plan swaps: {}; telemetry events:", adap.switches);
    for e in &adap.events {
        println!("  {:?} worker {} at round {}", e.kind, e.worker, e.round);
    }
    println!("final per-layer k: {:?}", adap.final_ks.first());

    // Heterogeneous refinement: jointly pick the worker subset + k for
    // the heaviest layer from the fitted per-worker speeds.
    let heavy = model
        .conv_layers()?
        .into_iter()
        .map(|(id, spec, (_, h, w))| (id, cocoi::latency::LayerDims::new(spec, h, w)))
        .max_by(|a, b| a.1.full_flops().partial_cmp(&b.1.full_flops()).unwrap())
        .unwrap();
    let replanner = Replanner::new(ReplanConfig::default());
    let mut rng = Rng::new(2);
    let hplan = replanner.plan_hetero(&adap.registry, &heavy.1, &p, 4_000, &mut rng);
    println!(
        "\nhetero refinement for {}: keep workers {:?}, k={} (E[T] {:.2}s)",
        heavy.0, hplan.workers, hplan.k, hplan.expected_latency
    );
    println!("\n(registry dump available via `cocoi infer --adaptive --telemetry out.json`)");
    Ok(())
}
