//! Scenario-1 (straggling) end to end, twice:
//!
//! 1. **Real execution** — TinyVGG on 6 in-process workers with injected
//!    exponential transmission delays (the testbed's manual sleeps),
//!    wall-clock timed, CoCoI vs uncoded vs replication.
//! 2. **Full-scale simulation** — VGG16 at n = 10 through the calibrated
//!    latency model (the Fig. 5 sweep).
//!
//! ```bash
//! cargo run --release --example vgg16_straggler
//! ```

use std::sync::Arc;

use cocoi::bench::experiments::{fig5, Scale};
use cocoi::conv::Tensor;
use cocoi::coordinator::{LocalCluster, MasterConfig, ScenarioFaults, SchemeKind};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::stats::Summary;
use cocoi::util::Rng;

fn wall_clock_run(scheme: SchemeKind, lambda_tr: f64, runs: usize) -> anyhow::Result<Summary> {
    let n = 6;
    // Mean "transmission" budget for the injected delay: ~15 ms per hop,
    // comparable to the real subtask latencies at this scale.
    let faults = ScenarioFaults::straggling(n, lambda_tr, 0.015);
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(4),
        ..Default::default()
    };
    let mut cluster =
        LocalCluster::spawn("tinyvgg", n, config, Arc::new(FallbackProvider::new()), faults)?;
    let mut rng = Rng::new(3);
    let mut s = Summary::new();
    for _ in 0..runs {
        let mut input = Tensor::zeros(3, 56, 56);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let t0 = std::time::Instant::now();
        let _ = cluster.master.infer(&input)?;
        s.push(t0.elapsed().as_secs_f64());
    }
    cluster.shutdown()?;
    Ok(s)
}

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();

    println!("== real execution: tinyvgg, n=6, injected straggling (λ_tr sweep) ==");
    println!(
        "{:<14} {:>10} {:>10} {:>10}",
        "scheme", "λ=0", "λ=0.5", "λ=1.0"
    );
    for scheme in [SchemeKind::Mds, SchemeKind::Uncoded, SchemeKind::Replication] {
        let mut cells = vec![format!("{:<14}", scheme.name())];
        for lambda in [0.0, 0.5, 1.0] {
            let s = wall_clock_run(scheme, lambda, 5)?;
            cells.push(format!("{:>9.0}ms", s.mean() * 1e3));
        }
        println!("{}", cells.join(" "));
    }
    println!(
        "(wall-clock on this 1-core host: absolute values compress because the\n\
         6 'devices' share a core, but the CoCoI-vs-uncoded ordering under\n\
         straggling is the paper's Fig. 5 effect)"
    );

    println!("\n== full-scale simulation: Fig. 5 sweep ==");
    fig5(Scale::from_env())?;
    Ok(())
}
