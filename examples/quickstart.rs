//! Quickstart: distribute a small CNN's conv layers over 4 in-process
//! workers with (4, 3)-MDS coding, run one inference, and check the
//! result against local (single-device) execution.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use cocoi::conv::Tensor;
use cocoi::coordinator::{LocalCluster, MasterConfig, SchemeKind, WorkerFaults};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();

    // 1. A model from the zoo (config/models.json) + deterministic weights.
    let model = zoo::model("tinyvgg")?;
    let weights = WeightStore::generate(&model, 42)?;
    println!("model: {} ({} parameters)", model.name, weights.num_params());

    // 2. Spawn a master + 4 workers; type-1 conv layers are split 3-ways
    //    and MDS-encoded into 4 subtasks, so any 3 results decode.
    let config = MasterConfig {
        scheme: SchemeKind::Mds,
        policy: SplitPolicy::Fixed(3),
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn(
        "tinyvgg",
        4,
        config,
        Arc::new(FallbackProvider::new()),
        (0..4).map(|_| WorkerFaults::none()).collect(),
    )?;

    // 3. Infer.
    let mut input = Tensor::zeros(3, 56, 56);
    Rng::new(7).fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let (output, metrics) = cluster.master.infer(&input)?;
    println!("\nper-layer latency breakdown:\n{}", metrics.table());

    // 4. Verify against local execution — MDS decoding is exact up to
    //    float round-off, so the distributed answer IS the local answer.
    let reference = forward_local(&model, &weights, &input)?;
    let err = output.max_abs_diff(&reference);
    println!("max |distributed − local| = {err:.2e}");
    assert!(err < 2e-2);
    println!("OK: coded distributed inference matches local inference.");

    cluster.shutdown()?;
    Ok(())
}
