//! Planner demo: the optimal-splitting machinery of §IV on the full-scale
//! VGG16/ResNet18 configs — k° vs k*, Prop. 1 sensitivity, and the
//! coded-vs-uncoded theory margin (Props. 2–3).
//!
//! ```bash
//! cargo run --release --example planner_demo
//! ```

use cocoi::latency::approx::{l_integer, uncoded_expectation};
use cocoi::latency::phases::LayerDims;
use cocoi::latency::SystemProfile;
use cocoi::model::zoo;
use cocoi::planner::{montecarlo, sensitivity, solve_k_circ, Param};
use cocoi::util::Rng;

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();
    let profile = SystemProfile::paper_default();
    let n = 10;
    let mut rng = Rng::new(2026);

    for name in ["vgg16", "resnet18"] {
        let model = zoo::model(name)?;
        println!("\n== {name}: per-layer k° (convex approx) vs k* (Monte-Carlo) ==");
        println!(
            "{:<10} {:>4} {:>4} {:>12} {:>12} {:>12}",
            "layer", "k0", "k*", "L(k0)", "E[T(k*)]", "uncoded E[T]"
        );
        for (id, spec, (_, h, w)) in model.conv_layers()? {
            let dims = LayerDims::new(spec, h, w);
            if dims.w_o < 2 {
                continue;
            }
            let kc = solve_k_circ(&dims, &profile, n);
            let (k_star, est) =
                montecarlo::optimal_k_star(&dims, &profile, n, 8_000, &mut rng);
            println!(
                "{:<10} {:>4} {:>4} {:>11.2}s {:>11.2}s {:>11.2}s",
                id,
                kc.k,
                k_star,
                l_integer(&dims, &profile, n, kc.k),
                est[k_star - 1],
                uncoded_expectation(&dims, &profile, n),
            );
        }
    }

    // Prop. 1: parameter sensitivity on a representative layer.
    let dims = LayerDims::new(cocoi::conv::ConvSpec::new(128, 128, 3, 1, 1), 112, 112);
    println!("\n== Prop. 1 sensitivity of k° (layer 128x128 3x3 @112) ==");
    for (param, values) in [
        (Param::MuCmp, vec![1e7, 1e8, 1e9, 1e10]),
        (Param::ThetaCmp, vec![1e-10, 1e-9, 1e-8, 1e-7]),
        (Param::MuTr, vec![1e6, 1e7, 1e8, 1e9]),
        (Param::ThetaM, vec![1e-11, 1e-10, 1e-9, 1e-8]),
    ] {
        let sweep = sensitivity::sweep_k_circ(&dims, &profile, n, param, &values);
        let ks: Vec<String> = sweep.iter().map(|(v, k)| format!("{v:.0e}->{k}")).collect();
        println!("{:<10} {}", param.name(), ks.join("  "));
    }
    println!(
        "\n(Prop. 1: k° increases in worker μ's and θ's, decreases as the \
         master weakens — larger θ_m)"
    );
    Ok(())
}
