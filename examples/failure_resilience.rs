//! Scenario-2/3 (device failure) end to end: real execution of TinyVGG
//! with injected per-round worker failures, showing that CoCoI absorbs
//! `n − k` failures with **zero re-dispatch** while uncoded must
//! re-execute every failed piece; then the Fig. 6 full-scale sweep.
//!
//! ```bash
//! cargo run --release --example failure_resilience
//! ```

use std::sync::Arc;

use cocoi::bench::experiments::{fig6, Scale};
use cocoi::conv::Tensor;
use cocoi::coordinator::{LocalCluster, MasterConfig, ScenarioFaults, SchemeKind};
use cocoi::model::graph::forward_local;
use cocoi::model::{zoo, WeightStore};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::FallbackProvider;
use cocoi::util::Rng;

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();
    let n = 6;
    let model = zoo::model("tinyvgg")?;
    let weights = WeightStore::generate(&model, 42)?;
    let mut input = Tensor::zeros(3, 56, 56);
    Rng::new(9).fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let reference = forward_local(&model, &weights, &input)?;

    println!("== real execution: tinyvgg, n=6, n_f=2 failures per round ==");
    println!(
        "{:<14} {:>9} {:>9} {:>12} {:>12} {:>10}",
        "scheme", "failures", "redisp", "latency", "max err", "correct"
    );
    for scheme in [SchemeKind::Mds, SchemeKind::Uncoded, SchemeKind::Replication] {
        let mut rng = Rng::new(1234);
        let faults = ScenarioFaults::failures(n, 2, 1024, &mut rng);
        let config = MasterConfig {
            scheme,
            // k = 4 with n = 6 leaves r = 2 — exactly n_f.
            policy: SplitPolicy::Fixed(4),
            ..Default::default()
        };
        let mut cluster =
            LocalCluster::spawn("tinyvgg", n, config, Arc::new(FallbackProvider::new()), faults)?;
        let t0 = std::time::Instant::now();
        let (out, metrics) = cluster.master.infer(&input)?;
        let dt = t0.elapsed().as_secs_f64();
        cluster.shutdown()?;
        let err = out.max_abs_diff(&reference);
        println!(
            "{:<14} {:>9} {:>9} {:>10.0}ms {:>12.2e} {:>10}",
            scheme.name(),
            metrics.failures(),
            metrics.redispatches(),
            dt * 1e3,
            err,
            err < 2e-2
        );
    }
    println!(
        "(CoCoI decodes from the surviving k workers — failures cost nothing;\n\
         uncoded re-dispatches every failed piece and pays for it)"
    );

    println!("\n== full-scale simulation: Fig. 6 (scenarios 2 and 3) ==");
    fig6(Scale::from_env())?;
    Ok(())
}
