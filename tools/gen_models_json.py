#!/usr/bin/env python
"""Generate config/models.json — the cross-language model zoo.

Run once (checked-in output); both rust (model::zoo) and python
(compile/models_zoo.py) parse the result. Regenerate with:
    python tools/gen_models_json.py > config/models.json
"""
import json
import sys


def vgg(name, input_hw, cfg, num_classes, fc_width):
    """VGG-style: cfg is a list of ints (conv out-channels) and 'M' (pool)."""
    layers = []
    prev = "input"
    c_in = 3
    idx = 0
    for v in cfg:
        if v == "M":
            lid = f"pool{idx}"
            layers.append({"id": lid, "op": "maxpool", "k": 2, "s": 2, "in": [prev]})
            prev = lid
        else:
            idx += 1
            lid = f"conv{idx}"
            layers.append({
                "id": lid, "op": "conv", "c_in": c_in, "c_out": v,
                "k": 3, "s": 1, "p": 1, "relu": True, "in": [prev],
            })
            prev = lid
            c_in = v
    # Classifier: GAP keeps the zoo weight counts manageable (torch VGG
    # uses 3 massive FC layers; the paper's experiments never distribute
    # them — they are type-2 either way).
    layers.append({"id": "gap", "op": "gap", "in": [prev]})
    layers.append({"id": "fc1", "op": "linear", "c_in": c_in, "c_out": fc_width,
                   "relu": True, "in": ["gap"]})
    layers.append({"id": "fc2", "op": "linear", "c_in": fc_width,
                   "c_out": num_classes, "in": ["fc1"]})
    return {"name": name, "input": [3, input_hw, input_hw], "layers": layers}


def resnet(name, input_hw, widths, blocks, num_classes, stem_k=7, stem_s=2, stem_p=3,
           stem_pool=True):
    """ResNet with BasicBlocks: widths per stage, blocks per stage."""
    layers = []
    conv_idx = 0

    def conv(c_in, c_out, k, s, p, relu, src):
        nonlocal conv_idx
        conv_idx += 1
        lid = f"conv{conv_idx}"
        layers.append({"id": lid, "op": "conv", "c_in": c_in, "c_out": c_out,
                       "k": k, "s": s, "p": p, "relu": relu, "in": [src]})
        return lid

    prev = conv(3, widths[0], stem_k, stem_s, stem_p, True, "input")
    if stem_pool:
        layers.append({"id": "pool1", "op": "maxpool", "k": 3, "s": 2, "p": 1,
                       "in": [prev]})
        prev = "pool1"
    c_in = widths[0]
    for stage, (w, nb) in enumerate(zip(widths, blocks)):
        for b in range(nb):
            stride = 2 if (stage > 0 and b == 0) else 1
            identity = prev
            x = conv(c_in, w, 3, stride, 1, True, prev)
            x = conv(w, w, 3, 1, 1, False, x)
            if stride != 1 or c_in != w:
                identity = conv(c_in, w, 1, stride, 0, False, identity)
            aid = f"add{stage+1}_{b+1}"
            layers.append({"id": aid, "op": "add", "relu": True, "in": [x, identity]})
            prev = aid
            c_in = w
    layers.append({"id": "gap", "op": "gap", "in": [prev]})
    layers.append({"id": "fc", "op": "linear", "c_in": c_in, "c_out": num_classes,
                   "in": ["gap"]})
    return {"name": name, "input": [3, input_hw, input_hw], "layers": layers}


MODELS = {
    "models": [
        # Full-scale configs (latency model / planner / DES figures).
        vgg("vgg16", 224,
            [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
             512, 512, 512, "M", 512, 512, 512, "M"],
            1000, 4096),
        resnet("resnet18", 224, [64, 128, 256, 512], [2, 2, 2, 2], 1000),
        # Scaled configs actually executed end-to-end on this testbed.
        vgg("tinyvgg", 56,
            [32, 32, "M", 64, 64, "M", 128, 128, "M"],
            10, 128),
        resnet("tinyresnet", 56, [16, 32, 64], [1, 1, 1], 10,
               stem_k=3, stem_s=1, stem_p=1, stem_pool=False),
    ]
}

if __name__ == "__main__":
    json.dump(MODELS, sys.stdout, indent=1)
    sys.stdout.write("\n")
